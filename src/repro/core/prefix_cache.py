"""Hybrid prefix cache pool (paper §3.2, Fig. 4).

Two KVCache group kinds share the unified BlockPool:

  * ``FullAttnGroup`` — block-level KVCache: grows with length, supports
    *partial* prefix matching (longest chain of block-hash matches).
  * ``LinearStateGroup`` — request-level recurrent states: O(1) size,
    reusable only when the cached length matches the new request's prefix
    *exactly* (states are snapshotted at block-aligned lengths).

For a hybrid model the resumable prefix is the longest block-aligned length
covered by BOTH groups — full-attn blocks give the KV, the linear snapshot
gives the recurrent state. For attention-only models it is the block match;
for pure-SSM models the snapshot match.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.blockpool import PREFIX, TRANSFER, BlockPool


def token_block_hashes(tokens: Sequence[int], block_tokens: int) -> List[int]:
    """Chained hashes, one per full block: h_i = H(h_{i-1}, block_i)."""
    out = []
    h = 0
    n_full = len(tokens) // block_tokens
    for i in range(n_full):
        blk = tuple(tokens[i * block_tokens:(i + 1) * block_tokens])
        h = hash((h,) + blk) & 0x7FFFFFFFFFFFFFFF
        out.append(h)
    return out


class FullAttnGroup:
    """Block-level prefix index: chain-hash -> block id."""

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self.index: Dict[int, int] = {}          # chain hash -> block id

    def match(self, hashes: Sequence[int]) -> List[int]:
        """Longest prefix of block ids present (populated blocks only)."""
        out = []
        for h in hashes:
            bid = self.index.get(h)
            if bid is None:
                break
            blk = self.pool._blocks.get(bid)
            if blk is None or not blk.populated:
                del self.index[h]
                break
            out.append(bid)
        self.pool.touch(out)
        return out

    def insert(self, hashes: Sequence[int], block_ids: Sequence[int]):
        """Register populated prefix blocks under their chain hashes."""
        self.pool.mark_populated(list(block_ids), keys=list(hashes))
        for h, bid in zip(hashes, block_ids):
            self.index[h] = bid

    def gc(self):
        dead = [h for h, bid in self.index.items()
                if bid not in self.pool._blocks]
        for h in dead:
            del self.index[h]


@dataclass
class LinearSnapshot:
    length: int                   # block-aligned prefix length
    chain_hash: int
    block_ids: List[int]          # pool blocks holding the state bytes
    payload: Optional[object] = None   # device state (SWA ring + linear leaves)


class LinearStateGroup:
    """Request-level state snapshots: exact-length prefix reuse."""

    def __init__(self, pool: BlockPool, state_bytes: int):
        self.pool = pool
        self.state_bytes = state_bytes
        self.blocks_per_state = max(1, -(-state_bytes // max(1, pool.block_bytes))
                                    if pool.block_bytes else 1)
        self.index: Dict[int, LinearSnapshot] = {}   # chain hash -> snapshot

    def match(self, hashes: Sequence[int]) -> Optional[LinearSnapshot]:
        """Longest exact snapshot at any block boundary of the new prefix."""
        for i in range(len(hashes) - 1, -1, -1):
            snap = self.index.get(hashes[i])
            if snap is not None:
                alive = all(b in self.pool._blocks for b in snap.block_ids)
                if alive:
                    self.pool.touch(snap.block_ids)
                    return snap
                del self.index[hashes[i]]
        return None

    def insert(self, length: int, chain_hash: int,
               payload: Optional[object] = None) -> Optional[LinearSnapshot]:
        if chain_hash in self.index:
            snap = self.index[chain_hash]
            if payload is not None and snap.payload is None:
                snap.payload = payload
            return snap
        bids = self.pool.allocate(self.blocks_per_state, PREFIX)
        if bids is None:
            return None
        self.pool.mark_populated(bids)
        snap = LinearSnapshot(length, chain_hash, bids, payload=payload)
        self.index[chain_hash] = snap
        self.pool.release(bids)            # cached (LRU), not pinned
        return snap


class HybridPrefixCache:
    """One per cluster: the paper's hybrid prefix cache pool."""

    def __init__(self, pool: BlockPool, kv_bytes_per_token_block: int,
                 linear_state_bytes: int, has_full_attn: bool = True,
                 has_linear: bool = True):
        self.pool = pool
        self.block_tokens = pool.block_tokens
        self.full = FullAttnGroup(pool) if has_full_attn else None
        self.linear = (LinearStateGroup(pool, linear_state_bytes)
                       if has_linear else None)
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0

    # ----------------------------------------------------------------- match
    def match(self, tokens: Sequence[int]) -> int:
        """Longest *resumable* cached prefix length (tokens)."""
        return self.match_hashes(token_block_hashes(tokens, self.block_tokens))

    def match_hashes(self, hashes: Sequence[int]) -> int:
        """Hash-chain variant (simulator fast path).

        Resumable = full-attn blocks cover [0, b) AND (for hybrid models) a
        linear state snapshot exists at exactly b.
        """
        if not hashes:
            return 0
        if self.full is not None:
            covered_blocks = len(self.full.match(hashes))
        else:
            covered_blocks = len(hashes)
        if self.linear is None:
            matched = covered_blocks * self.block_tokens
        else:
            snap = self.linear.match(hashes[:covered_blocks])
            matched = 0 if snap is None else min(
                snap.length, covered_blocks * self.block_tokens)
        if matched:
            self.hits += 1
            self.hit_tokens += matched
        else:
            self.misses += 1
        return matched

    def match_resume(self, tokens: Sequence[int], *,
                     require_payload: bool = True
                     ) -> Tuple[int, List[int], Optional[LinearSnapshot]]:
        """Device-resumable prefix: ``(cached_len, seq_page_ids, snapshot)``.

        Unlike :meth:`match` (routing metadata), this returns the actual
        page handles a paged `DecodeEngine` can resume from. The cached
        length is capped at the last *full* page strictly before the prompt
        end so at least the final token is always recomputed (its logits
        seed decode). For models whose resume needs an exact-length state
        (SWA ring / linear mixers — ``has_linear``), a snapshot carrying a
        device payload must exist at exactly the cached length; otherwise
        the hit degrades to a miss. Does not touch hit/miss counters (the
        routing-level ``match`` already accounts those); the caller must
        ``pool.retain`` the returned ids to pin them.
        """
        L = len(tokens)
        hashes = token_block_hashes(tokens, self.block_tokens)
        max_blocks = max(0, (L - 1) // self.block_tokens)
        hashes = hashes[:max_blocks]
        if not hashes:
            return 0, [], None
        if self.full is not None:
            ids = self.full.match(hashes)
            covered = len(ids)
        else:
            ids = []
            covered = len(hashes)
        if covered == 0:
            return 0, [], None
        if self.linear is None:
            return covered * self.block_tokens, ids, None
        snap = self.linear.match(hashes[:covered])
        if snap is None or (require_payload and snap.payload is None):
            return 0, [], None
        c = min(snap.length, covered * self.block_tokens)
        if c != snap.length:
            # exact-length state does not cover the full-attn match; the
            # state is only valid at snap.length, so no resumable prefix
            return 0, [], None
        return c, ids[:c // self.block_tokens], snap

    def insert_device(self, tokens: Sequence[int], seq_ids: Sequence[int] = (),
                      snapshot_payload: Optional[object] = None) -> int:
        """Register *device* pages holding a prompt's prefix.

        ``seq_ids``: ref-held pool pages (one per full prompt page, in
        order) that a paged DecodeEngine wrote the full-attn/MLA KV into;
        indexed under the chain hashes so later requests can resume from
        them. ``snapshot_payload``: exact-length device state (SWA ring +
        linear leaves) — only registered when the prompt length is
        page-aligned, because prefill yields the state at exactly L.
        Caller keeps its refs; pages become LRU-cached when those drop.
        """
        hashes = token_block_hashes(tokens, self.block_tokens)
        if not hashes:
            return 0
        cached = 0
        if self.full is not None and seq_ids:
            n = min(len(hashes), len(seq_ids))
            self.full.insert(hashes[:n], list(seq_ids)[:n])
            cached = n * self.block_tokens
        if (self.linear is not None and snapshot_payload is not None
                and len(tokens) % self.block_tokens == 0):
            snap = self.linear.insert(len(hashes) * self.block_tokens,
                                      hashes[-1], payload=snapshot_payload)
            if snap is not None and self.full is None:
                cached = max(cached, snap.length)
        return cached

    # ---------------------------------------------------------------- insert
    def insert(self, tokens: Sequence[int]) -> int:
        return self.insert_hashes(token_block_hashes(tokens,
                                                     self.block_tokens))

    def insert_hashes(self, hashes: Sequence[int]) -> int:
        """Record the KV/state produced by a completed prefill.

        Allocates prefix blocks for the full-attn KV and one linear snapshot
        at the final block boundary. Returns cached length (tokens); 0 if the
        pool was too full.
        """
        if not hashes:
            return 0
        cached = 0
        if self.full is not None:
            have = self.full.match(hashes)
            need = len(hashes) - len(have)
            if need > 0:
                bids = self.pool.allocate(need, PREFIX)
                if bids is None:
                    return 0
                self.full.insert(hashes[len(have):], bids)
                self.pool.release(bids)        # cached, evictable
            cached = len(hashes) * self.block_tokens
        if self.linear is not None:
            snap = self.linear.insert(len(hashes) * self.block_tokens,
                                      hashes[-1])
            if snap is not None:
                cached = max(cached, snap.length) if self.full is None \
                    else cached
        return cached

    # ------------------------------------------------------------- transfer
    def allocate_transfer(self, n_tokens: int) -> Optional[List[int]]:
        """Transfer-cache blocks for the tail KV of a PD-disaggregated
        prefill; discarded via ``release_transfer`` when the wire is done."""
        n = -(-n_tokens // self.block_tokens)
        return self.pool.allocate(n, TRANSFER)

    def release_transfer(self, block_ids: List[int]):
        self.pool.release(block_ids)

    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic.

Design for 1000+ nodes:
  * leaves are stored logically-unsharded (np arrays in an .npz per bundle)
    with a JSON manifest carrying step, flat-key list, and a mesh
    fingerprint — restores can re-shard onto a *different* mesh (elastic
    restart after losing a pod);
  * writes go to ``<dir>/tmp-<step>`` then atomically ``rename`` to
    ``step-<n>`` — a crash mid-write never corrupts the latest checkpoint;
  * async flush on a background thread (the train loop donates a host copy
    and keeps stepping — checkpoint I/O overlaps compute);
  * retention policy keeps the newest K checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.save_count = 0

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, mesh_fingerprint: str = "",
             blocking: bool = True):
        """Snapshot to host memory, then write (optionally async)."""
        host = _flatten(tree)                      # device->host copy now

        def _write():
            tmp = os.path.join(self.dir, f"tmp-{step}-{os.getpid()}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "leaves.npz"), **
                     {k.replace("/", _SEP): v for k, v in host.items()})
            manifest = {"step": step, "keys": sorted(host.keys()),
                        "mesh": mesh_fingerprint,
                        "time": time.time()}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.dir, f"step-{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                  # atomic publish
            self._retain()
            self.save_count += 1

        if blocking:
            _write()
        else:
            self.wait()                            # one async save in flight
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                try:
                    out.append(int(name.split("-")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``like_tree``; re-shards leaves if
        ``shardings`` (a matching pytree of NamedSharding) is given —
        this is what makes elastic re-mesh restarts possible."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step-{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "leaves.npz"))
        flat = jax.tree_util.tree_flatten_with_path(like_tree)
        leaves = []
        for p, leaf in flat[0]:
            key = jax.tree_util.keystr(p).replace("/", _SEP)
            arr = data[key]
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(flat[1], leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest

"""Deterministic, resumable synthetic data pipeline.

Stateless index-based generation: batch ``i`` is a pure function of
(seed, i), so restart-after-failure resumes exactly (no shard state to
persist beyond the step counter). Shards along the data axis by slicing the
global batch — each host generates only its shard in a multi-host setup.

Sequences are Zipf-ish token streams with enough autocorrelation that CE
loss decreases during the smoke-training examples (pure uniform noise has
no learnable signal).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed bigram transition structure => learnable signal
        self._shift = base.integers(1, v, size=v)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        v = cfg.vocab_size
        first = rng.choice(v, size=(cfg.global_batch,), p=self._probs)
        toks = np.empty((cfg.global_batch, cfg.seq_len + 1), np.int32)
        toks[:, 0] = first
        noise = rng.random((cfg.global_batch, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = self._shift[toks[:, t]]
            resample = noise[:, t] < 0.15
            if resample.any():
                nxt = np.where(resample,
                               rng.choice(v, size=cfg.global_batch,
                                          p=self._probs), nxt)
            toks[:, t + 1] = nxt
        return {"tokens": toks}

    def shard(self, step: int, shard_idx: int, num_shards: int) -> dict:
        b = self.batch(step)
        per = self.cfg.global_batch // num_shards
        return {k: v[shard_idx * per:(shard_idx + 1) * per]
                for k, v in b.items()}

"""AdamW (from scratch — no optax dependency) with fp32 master state,
global-norm clipping, and cosine/linear LR schedules.

State layout: {"step", "mu", "nu", "master"} — master weights kept in fp32
when params are bf16 (mixed-precision training standard).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.distributed.collectives import global_norm


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # explicit copy: fp32 params would otherwise alias the master buffer,
    # breaking donation (donate(params) + donate(master) of one buffer)
    master = jax.tree.map(lambda p: jnp.array(p, dtype=jnp.float32,
                                              copy=True), params)
    return {"step": jnp.zeros((), jnp.int32), "mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros), "master": master}


def _decay_mask(path) -> bool:
    """Apply weight decay to matrices only (not norms/biases/gates)."""
    name = jax.tree_util.keystr(path)
    return not any(k in name for k in ("norm", "ln", "bias", "b_gates",
                                       "A_log", "dt_bias", "D_skip"))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    g_l = jax.tree.leaves(grads)
    mu_l = jax.tree.leaves(state["mu"])
    nu_l = jax.tree.leaves(state["nu"])
    ma_l = jax.tree.leaves(state["master"])

    new_p, new_mu, new_nu, new_ma = [], [], [], []
    for (path, p), g, mu, nu, ma in zip(flat, g_l, mu_l, nu_l, ma_l):
        gf = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * gf
        nu = b2 * nu + (1 - b2) * gf * gf
        upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if _decay_mask(path):
            upd = upd + cfg.weight_decay * ma
        ma = ma - lr * upd
        new_p.append(ma.astype(p.dtype))
        new_mu.append(mu)
        new_nu.append(nu)
        new_ma.append(ma)

    unflatten = jax.tree_util.tree_structure(params).unflatten
    new_params = unflatten(new_p)
    new_state = {"step": step, "mu": unflatten(new_mu),
                 "nu": unflatten(new_nu), "master": unflatten(new_ma)}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}

from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamWConfig, apply_updates, init_state
from repro.training.train_loop import (TrainConfig, TrainLoop,
                                       init_opt_state, make_train_step)

__all__ = [
    "CheckpointManager", "DataConfig", "SyntheticLM", "AdamWConfig",
    "apply_updates", "init_state", "TrainConfig", "TrainLoop",
    "init_opt_state", "make_train_step",
]

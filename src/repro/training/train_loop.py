"""Fault-tolerant distributed train loop.

``make_train_step`` builds the jit'd step with:
  * gradient accumulation over microbatches (``lax.scan``) — bounds live
    activation memory and pipelines the per-microbatch all-reduces behind
    the next microbatch's compute (collective/compute overlap);
  * per-block remat (``jax.checkpoint`` inside the layer scan);
  * optional int8 error-feedback gradient compression before the optimizer;
  * donated params/opt-state (in-place buffers).

``TrainLoop`` adds production concerns: checkpoint/restart (async, atomic),
straggler detection (per-step wall-time EWMA + deviation callback), crash
recovery (resume-exact via the stateless data pipeline), and a simulated
node-failure hook used by the fault-tolerance tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed.collectives import (compress_grads_with_feedback,
                                           zeros_like_residuals)
from repro.models.model import Model
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: bool = True
    compress_grads: bool = False
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_async: bool = True
    keep_checkpoints: int = 3
    straggler_factor: float = 3.0     # step slower than EWMA x this => flag
    unroll: bool = False              # cost-probe mode


def make_train_step(model: Model, cfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Batch leading dim must be divisible by cfg.microbatches."""

    def loss_fn(params, mb):
        loss, metrics = model.train_loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        mb_count = cfg.microbatches

        if mb_count > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(mb_count, b // mb_count, *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_sum), _ = jax.lax.scan(
                accum, (g0, 0.0), mbs, unroll=True if cfg.unroll else 1)
            grads = jax.tree.map(lambda g: g / mb_count, grads)
            loss = loss_sum / mb_count
        else:
            (loss, _), grads = grad_fn(params, batch)

        if cfg.compress_grads:
            residuals = opt_state["residuals"]
            grads, residuals = compress_grads_with_feedback(grads, residuals)
            opt_state = {**opt_state, "residuals": residuals}

        inner = {k: v for k, v in opt_state.items() if k != "residuals"}
        params, inner, om = opt.apply_updates(params, grads, inner, cfg.adamw)
        if cfg.compress_grads:
            inner["residuals"] = opt_state["residuals"]
        metrics = {"loss": loss, **om}
        return params, inner, metrics

    return train_step


def init_opt_state(params, cfg: TrainConfig):
    state = opt.init_state(params)
    if cfg.compress_grads:
        state["residuals"] = zeros_like_residuals(params)
    return state


@dataclass
class TrainLoop:
    model: Model
    cfg: TrainConfig
    data: object                       # .batch(step) -> dict
    mesh_fingerprint: str = ""
    on_straggler: Optional[Callable[[int, float], None]] = None
    fail_at_step: Optional[int] = None   # fault-injection (tests)

    def run(self, params, opt_state, num_steps: int, jit: bool = True,
            start_step: Optional[int] = None):
        step_fn = make_train_step(self.model, self.cfg)
        if jit:
            step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        ckpt = CheckpointManager(self.cfg.checkpoint_dir,
                                 keep=self.cfg.keep_checkpoints)

        if start_step is None:
            latest = ckpt.latest_step()
            start_step = 0
            if latest is not None:
                restored, manifest = ckpt.restore(
                    {"params": params, "opt": opt_state})
                params, opt_state = restored["params"], restored["opt"]
                start_step = manifest["step"]

        ewma = None
        history = []
        for step in range(start_step, num_steps):
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"injected node failure at step {step}")
            batch = self.data.batch(step)
            batch = jax.tree.map(jnp.asarray, batch)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            # straggler mitigation hook: flag steps far above the EWMA
            if ewma is None:
                ewma = dt
            else:
                if dt > self.cfg.straggler_factor * ewma \
                        and self.on_straggler is not None:
                    self.on_straggler(step, dt / ewma)
                ewma = 0.9 * ewma + 0.1 * dt
            history.append({"step": step, "loss": float(metrics["loss"]),
                            "time_s": dt,
                            "grad_norm": float(metrics["grad_norm"])})
            if (step + 1) % self.cfg.checkpoint_every == 0 \
                    or step + 1 == num_steps:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          self.mesh_fingerprint,
                          blocking=not self.cfg.checkpoint_async)
        ckpt.wait()
        return params, opt_state, history

"""Sharding rules: map every param/activation/cache leaf to a PartitionSpec.

Mesh axes (see launch/mesh.py):
  * "pod"   — hierarchical data parallelism across pods (multi-pod only)
  * "data"  — data parallelism within a pod
  * "model" — tensor parallelism (heads / d_ff / experts-dff / vocab)

Rules (MaxText-style, but derived from leaf path + shape):
  * embed / unembed: vocab dim over "model"
  * attention wq/wk/wv: output (heads*dim) over "model" when divisible,
    else replicated (GQA kv_heads < 16); wo: input over "model"
  * FFN w1/w3: d_ff over "model"; w2: d_ff (input) over "model"
  * MoE w1/w3/w2: d_ff dim over "model" (TP-within-expert — works for any
    expert count on a 16-way axis); router replicated
  * norms / biases / gates: replicated
  * batch dims of inputs & caches: over ("pod","data") when divisible
  * optional sequence parallelism: activations sharded on seq over "model"
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import AbstractMesh, Mesh, NamedSharding, PartitionSpec as P


def abstract_mesh(axis_sizes, axis_names) -> AbstractMesh:
    """Version-compat constructor for ``jax.sharding.AbstractMesh``.

    jax <= 0.4.x takes a single ``shape_tuple`` of (name, size) pairs;
    jax >= 0.5 takes ``(axis_sizes, axis_names)``.  Accepts either call
    style's data and dispatches to whichever the installed jax supports:

        abstract_mesh((16, 16), ("data", "model"))
    """
    try:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.shape and dim % mesh.shape[axis] == 0 and dim > 0


def _data_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes if axes else None


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _with_fsdp(spec: P, shape, mesh: Mesh) -> P:
    """Add ZeRO-3-style param sharding: pick the largest dim not already
    sharded and split it over "data" (XLA all-gathers per use). Essential
    to fit 100B+ param/optimizer state on 16 GB v5e chips."""
    if "data" not in mesh.shape:
        return spec
    specs = list(spec) + [None] * (len(shape) - len(spec))
    cands = [(shape[d], d) for d in range(len(shape))
             if specs[d] is None and _divisible(shape[d], mesh, "data")
             and shape[d] >= 2 * mesh.shape["data"]]
    if not cands:
        return spec
    _, d = max(cands)
    specs[d] = "data"
    return P(*specs)


def param_pspec(path: str, shape, mesh: Mesh, fsdp: bool = False) -> P:
    """PartitionSpec for a parameter leaf, by path + shape heuristics."""
    spec = _param_pspec_base(path, shape, mesh)
    if fsdp:
        spec = _with_fsdp(spec, shape, mesh)
    return spec


def _param_pspec_base(path: str, shape, mesh: Mesh) -> P:
    nd = len(shape)
    last = path.rsplit("/", 1)[-1]

    def model_ok(d):
        return _divisible(shape[d], mesh, "model")

    # --- embeddings ---------------------------------------------------------
    if last == "unembed" and nd == 2:              # (d, V): shard vocab
        return P(None, "model") if model_ok(1) else P(None, None)
    if last == "embed" and nd == 2:                # (V, d): shard vocab
        return P("model", None) if model_ok(0) else P(None, None)

    # --- MoE expert weights (E, d, f) / (E, f, d): shard d_ff ---------------
    if re.search(r"ffn/w[13]$", path) and nd == 3:
        return P(None, None, "model") if model_ok(2) else P(None, None, None)
    if path.endswith("ffn/w2") and nd == 3:
        return P(None, "model", None) if model_ok(1) else P(None, None, None)
    # stacked (R, E, d, f) variants (scan-stacked MoE)
    if re.search(r"ffn/w[13]$", path) and nd == 4:
        return P(None, None, None, "model") if model_ok(3) else P(*([None] * 4))
    if path.endswith("ffn/w2") and nd == 4:
        return P(None, None, "model", None) if model_ok(2) else P(*([None] * 4))
    if "router" in path:
        return P(*([None] * nd))

    # --- dense FFN (d, f) / (f, d), possibly stacked (R, ...) ---------------
    if re.search(r"(ffn|shared)/w[13]$", path):
        specs = [None] * nd
        if model_ok(nd - 1):
            specs[nd - 1] = "model"
        return P(*specs)
    if re.search(r"(ffn|shared)/w2$", path):
        specs = [None] * nd
        if model_ok(nd - 2):
            specs[nd - 2] = "model"
        return P(*specs)

    # --- attention / linear-mixer projections -------------------------------
    if re.search(r"w(q|k|v|q_a|q_b|kv_a|kv_b)(/w)?$", path) or \
            re.search(r"(g_proj|i_proj|f_proj|a_proj|b_proj|w_gates)/w$", path):
        specs = [None] * nd
        if model_ok(nd - 1):
            specs[nd - 1] = "model"                # shard output features
        return P(*specs)
    if re.search(r"wo/w$", path):
        specs = [None] * nd
        if model_ok(nd - 2):
            specs[nd - 2] = "model"                # shard input features
        return P(*specs)

    # --- everything else (norms, biases, gates, convs) ----------------------
    return P(*([None] * nd))


def params_shardings(params, mesh: Mesh, fsdp: bool = False):
    """Pytree of NamedSharding matching ``params`` (works on shape structs)."""

    def spec(path, leaf):
        return NamedSharding(mesh, param_pspec(_leaf_name(path), leaf.shape,
                                               mesh, fsdp=fsdp))

    return jax.tree_util.tree_map_with_path(spec, params)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------


def batch_pspec(shape, mesh: Mesh, seq_axis: Optional[int] = None,
                shard_seq_over_data: bool = False) -> P:
    """Shard leading batch dim over ("pod","data"); optionally shard a seq
    axis over "data" (long-context decode with batch=1)."""
    axes = _data_axes(mesh)
    nd = len(shape)
    specs = [None] * nd
    if axes:
        total = int(np.prod([mesh.shape[a] for a in axes]))
        if shape[0] % total == 0 and shape[0] >= total:
            specs[0] = axes if len(axes) > 1 else axes[0]
    if (shard_seq_over_data and seq_axis is not None and specs[0] is None
            and "data" in mesh.shape
            and shape[seq_axis] % mesh.shape["data"] == 0):
        specs[seq_axis] = "data"
    return P(*specs)


def batch_shardings(batch, mesh: Mesh):
    def spec(path, leaf):
        return NamedSharding(mesh, batch_pspec(leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_shardings(caches, mesh: Mesh, shard_seq_over_data: bool = False,
                    shard_headdim: bool = False):
    """Decode caches: (R, B, S, ...) — batch dim is axis 1; for batch=1
    long-context, shard the seq axis (flash-decode style) instead."""

    def spec(path, leaf):
        name = _leaf_name(path).rsplit("/", 1)[-1]
        shape = leaf.shape
        nd = len(shape)
        specs = [None] * nd
        axes = _data_axes(mesh)
        if axes and nd >= 2:
            total = int(np.prod([mesh.shape[a] for a in axes]))
            if shape[1] % total == 0 and shape[1] >= total:
                specs[1] = axes if len(axes) > 1 else axes[0]
            elif (shard_seq_over_data and name in ("k", "v", "ckv", "kpe")
                  and nd >= 3 and "data" in mesh.shape
                  and shape[2] % mesh.shape["data"] == 0):
                specs[2] = "data"
        # shard kv heads / head-state over model where divisible
        if name in ("k", "v") and nd >= 4 and _divisible(shape[3], mesh,
                                                         "model"):
            specs[3] = "model"
        elif (shard_headdim and name in ("k", "v") and nd >= 5
                and _divisible(shape[4], mesh, "model")):
            # GQA with kv_heads < |model|: shard head_dim (contracting dim;
            # XLA emits partial scores + all-reduce) instead of replicating
            specs[4] = "model"
        if name == "state" and nd >= 3 and _divisible(shape[2], mesh,
                                                      "model"):
            specs[2] = "model"
        return NamedSharding(mesh, P(*specs))

    return jax.tree_util.tree_map_with_path(spec, caches)

from repro.distributed.collectives import (compress_grads_with_feedback,
                                           dequantize_int8, global_norm,
                                           quantize_int8,
                                           zeros_like_residuals)
from repro.distributed.sharding import (batch_pspec, batch_shardings,
                                        cache_shardings, param_pspec,
                                        params_shardings)

__all__ = [
    "batch_pspec", "batch_shardings", "cache_shardings", "param_pspec",
    "params_shardings", "quantize_int8", "dequantize_int8", "global_norm",
    "compress_grads_with_feedback", "zeros_like_residuals",
]

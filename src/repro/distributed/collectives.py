"""Distributed-optimization tricks: compressed gradient all-reduce with
error feedback, and helpers for hierarchical (pod-aware) reduction.

Gradient compression (int8 + per-tensor scale, error-feedback residual) cuts
cross-pod all-reduce bytes 4x for the multi-pod mesh's slow "pod" axis —
the classic 1-bit-Adam / PowerSGD-family trade, here in its simplest robust
form. Used by the train loop when ``TrainConfig.compress_grads`` is set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Symmetric per-tensor int8 quantization. Returns (q, scale).

    Reciprocal multiply (not /127) keeps the scale bit-identical between
    eager and jitted execution — jit rewrites constant divisions anyway."""
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-30) * (1.0 / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(grads, residuals):
    """Error-feedback compression: g' = Q(g + r); r' = (g + r) - g'.

    Under jit+GSPMD the quantized tensors are what cross the network in the
    gradient all-reduce (XLA reduces the dequantized values, but the HLO
    keeps the int8 representation live across the collective boundary when
    donated); the residual keeps the scheme unbiased over time.
    """

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = tdef.unflatten([o[0] for o in out])
    new_r = tdef.unflatten([o[1] for o in out])
    return new_g, new_r


def zeros_like_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))

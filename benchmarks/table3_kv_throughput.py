"""Paper Table 3 + Figure 2: KV throughput Φ_kv(l) across model families.

Reproduces the paper's central measurement: hybrid-attention models emit an
order of magnitude less KVCache per unit prefill time than dense-attention
models, moving PD disaggregation from RDMA-class into Ethernet territory.

S_kv(l) is exact (config KV accounting); T_prefill(l) comes from the
AnalyticProfile roofline on an 8xH200-class instance — absolute Gbps differ
from the paper's SGLang measurements, but the dense/hybrid gap (the claim)
must reproduce.
"""
import time

from benchmarks.common import emit
from repro.configs.profiles import PROFILE_MODELS
from repro.core.hardware import CHIPS, AnalyticProfile
from repro.core.throughput_model import kv_throughput

LENS = (1024, 8192, 32768, 131072)

# paper Table 3 (Gbps) for claim-checking the dense/hybrid gap
PAPER_T3_32K = {"kimi-linear-48b": 3.87, "mimo-v2-flash": 4.66,
                "qwen3.5-397b": 8.25, "ring-2.5-1t": 2.59,
                "minimax-m2.5": 59.93, "qwen3-235b": 33.35}
HYBRID = ("kimi-linear-48b", "mimo-v2-flash", "qwen3.5-397b", "ring-2.5-1t")
DENSE = ("minimax-m2.5", "qwen3-235b")


def main():
    t0 = time.time()
    gbps32 = {}
    for name, build in PROFILE_MODELS.items():
        cfg = build()
        prof = AnalyticProfile(cfg, CHIPS["h200"], chips_per_instance=8)
        for l in LENS:
            phi = kv_throughput(prof, l) * 8 / 1e9           # Gbps
            if l == 32768:
                gbps32[name] = phi
            emit(f"table3/{name}/phi_kv_{l//1024}k",
                 (time.time() - t0) * 1e6 / max(1, len(gbps32)),
                 f"{phi:.2f}Gbps skv={cfg.kv_cache_bytes(l)/2**20:.0f}MiB "
                 f"tprefill={prof.t_prefill(l):.2f}s")
    hybrid_mean = sum(gbps32[m] for m in HYBRID) / len(HYBRID)
    dense_mean = sum(gbps32[m] for m in DENSE) / len(DENSE)
    gap = dense_mean / hybrid_mean
    paper_gap = (sum(PAPER_T3_32K[m] for m in DENSE) / 2) / \
        (sum(PAPER_T3_32K[m] for m in HYBRID) / 4)
    emit("table3/dense_over_hybrid_gap_32k", 0.0,
         f"ours={gap:.1f}x paper={paper_gap:.1f}x "
         f"claim={'REPRODUCED' if gap > 4 else 'NOT-REPRODUCED'}")
    return gap


if __name__ == "__main__":
    main()

"""Scenario engine: stressor grid + trace-driven workload sweeps with an
SLO-attainment / cost frontier.

Part 1 — the figure-style stressor grid (event engine, unchanged axes):

  * burst_factor      — MMPP arrival burstiness (mean-preserving duty cycle)
  * length skew       — log-normal sigma of the request-length distribution
  * link fluctuation  — OU bandwidth noise on every inter-DC pair link
  * topology          — 1 vs 3 regional PD clusters (star + PD mesh, skewed
                        regional traffic shares, per-region link capacities)

Multi-cluster points run the regionalized control plane: per-home routing
thresholds (reported per point) and session roaming (``ROAM_PROB``), so
the PD<->PD mesh links carry cross-region cache copies.  Every point runs
the SAME offered load (a fixed fraction of the paper deployment's modeled
capacity) so degradation is attributable to the stressor, not re-sizing.

Part 2 — the trace-driven scenario sweep (vector engine, the fast path
that makes this affordable): replayable ``core.workload`` traces over

    workload family x topology x policy x fleet size

  * families  — diurnal (regional tz-offset peaks), flash_crowd (viral
                onsets), conversation (multi-turn trees w/ think time)
  * topology  — 1 pooled vs 3 regional PD clusters
  * policy    — static threshold / adaptive routing / adaptive+autoscale
  * size      — fleet provisioning multiplier at FIXED demand, tracing
                out the cost vs SLO-attainment tradeoff

Each point reports TTFT P99, SLO attainment, goodput, and dollar cost per
million completed requests; per family the Pareto-optimal (cost,
attainment) points form the frontier consumed by
``examples/capacity_planner.py``.  Emits ``BENCH_scenario_grid.json``.

    PYTHONPATH=src python -m benchmarks.scenario_grid [--smoke]
"""
import argparse
import dataclasses
import itertools
import time

from benchmarks.common import emit, write_json
from repro.core import (LogNormalLengths, PrfaasSimulator, RouterConfig,
                        SimConfig, SystemConfig, ThroughputModel, Workload,
                        conversation_trace, diurnal_trace, flash_crowd_trace,
                        paper_h20_profile, paper_h200_profile, split_even)

BURST_FACTORS = (1.0, 2.5)
LENGTH_SIGMAS = (1.0, 1.3)
FLUCTUATIONS = (0.0, 0.3)
PD_CLUSTERS = (1, 3)
SHARES_3 = (0.6, 0.3, 0.1)           # skewed regional traffic
# deliberately skinny Ethernet (mean egress is ~7 Gbps): OU fluctuation can
# push a pair link into congestion, exercising the short-term routing loop
LINK_GBPS_1 = 20.0
LINK_GBPS_3 = (14.0, 8.0, 5.0)       # thinner links to smaller regions
ROAM_PROB = 0.15                     # multi-cluster: sessions switch region
SLO_TTFT_S = 4.0                     # TTFT SLO for attainment/goodput


def _system(tm: ThroughputModel, k: int):
    sc, lam, _ = tm.grid_search(4, 9, 100e9 / 8)
    if k == 1:
        return sc, lam
    sc_k = SystemConfig(sc.n_prfaas, sc.n_p, sc.n_d, sc.b_out, sc.threshold,
                        n_p_clusters=tuple(split_even(sc.n_p, k)),
                        n_d_clusters=tuple(split_even(sc.n_d, k)))
    return sc_k, lam


def run_point(bf: float, sigma: float, fluct: float, k: int,
              sim_time: float, load_frac: float = 0.7) -> dict:
    w = Workload(lengths=LogNormalLengths(sigma=sigma), burst_factor=bf,
                 session_prob=0.3)
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, lam = _system(tm, k)
    cfg = SimConfig(
        arrival_rate=load_frac * lam, sim_time=sim_time, seed=17,
        link_gbps=LINK_GBPS_1, link_fluctuation=fluct, engine="event",
        ttft_slo_s=SLO_TTFT_S, pd_clusters=k,
        pd_shares=SHARES_3[:k] if k > 1 else None,
        pd_link_gbps=LINK_GBPS_3[:k] if k > 1 else None,
        pd_mesh_gbps=10.0 if k > 1 else 0.0,
        roam_prob=ROAM_PROB if k > 1 else 0.0)
    t0 = time.time()
    m = PrfaasSimulator(tm, sc, w, cfg).run()

    def _r(v):
        return round(v, 4) if v == v else None    # NaN -> valid JSON null

    return {
        "burst_factor": bf, "length_sigma": sigma,
        "link_fluctuation": fluct, "pd_clusters": k,
        "offered_rps": round(load_frac * lam, 4),
        "wall_s": round(time.time() - t0, 3),
        "throughput_rps": round(m["throughput_rps"], 4),
        "ttft_mean_s": _r(m["ttft_mean"]),
        "ttft_p90_s": _r(m["ttft_p90"]),
        "ttft_p99_s": _r(m["ttft_p99"]),
        "slo_attainment": _r(m["slo_attainment"]),
        "goodput_rps": _r(m["goodput_rps"]),
        "egress_gbps": round(m["egress_gbps"], 4),
        "offload_frac": round(m["offload_frac"], 4),
        "thresholds": {name: _r(t) for name, t in m["thresholds"].items()},
        "clusters": {name: {kk: _r(vv) for kk, vv in c.items()}
                     for name, c in m["clusters"].items()},
        # per pair link: cumulative GB on the wire + the windowed drop
        # signal at sim end (the congestion telemetry routing acts on)
        "links": {pair: {"gb": round(s["sent_bytes"] / 1e9, 3),
                         "drops": round(s["drops"], 4)}
                  for pair, s in m["links"].items()},
    }


# ---------------------------------------------------------------------------
# trace-driven scenario sweep (vector engine)
# ---------------------------------------------------------------------------
FAMILIES = ("diurnal", "flash_crowd", "conversation")
POLICIES = ("static", "adaptive", "autoscale")
SIZES = (0.6, 1.0, 1.75)             # fleet multiplier at fixed demand
SCEN_K = (1, 3)
SCEN_SEED = 23
SCEN_BASE_SCALE = 4                  # base fleet = 4x the paper deployment
SCEN_LOAD_FRAC = 0.5                 # demand sized for SIZES==1.0 @ 50%
                                     # (diurnal peak = 1.6x mean -> 80%)
SCEN_SHARES = (0.5, 0.3, 0.2)
SCEN_TZ_FRAC = (0.0, 1.0 / 3.0, 2.0 / 3.0)   # regional peak phase offsets
# $/instance-hour (indicative on-demand 8-GPU node prices): prefill-class
# nodes (H200-like, also PrfaaS) vs decode-class nodes (H20-like)
PRICE_HR = {"prefill": 70.0, "decode": 28.0, "prfaas": 70.0}


def _scaled_system(sc0, mult: float) -> SystemConfig:
    return dataclasses.replace(
        sc0, n_prfaas=max(1, round(sc0.n_prfaas * mult)),
        n_p=max(1, round(sc0.n_p * mult)), n_d=max(1, round(sc0.n_d * mult)),
        b_out=sc0.b_out * mult)


def _make_trace(family: str, rate: float, sim_time: float, k: int,
                names, shares):
    """Build the family's replayable ``core.workload`` trace at a common
    mean demand ``rate`` (flash crowds add transient load on top — that is
    the family's stressor)."""
    if family == "diurnal":
        # one full (compressed) day so every region sees its peak
        return diurnal_trace(rate, sim_time, seed=SCEN_SEED,
                             home_names=names, shares=shares,
                             tz_offsets_s=[f * sim_time
                                           for f in SCEN_TZ_FRAC[:k]],
                             day_s=sim_time)
    if family == "flash_crowd":
        return flash_crowd_trace(rate, sim_time, seed=SCEN_SEED,
                                 home_names=names, shares=shares,
                                 flash_times=(0.35 * sim_time,
                                              0.7 * sim_time),
                                 flash_amp=2.0, flash_decay_s=45.0)
    # conversation: Poisson session starts; turns_mean turns/session keeps
    # the mean REQUEST rate at ~rate; per-turn roaming when multi-region
    turns_mean = 4.0
    starts = diurnal_trace(rate / turns_mean, sim_time, seed=SCEN_SEED,
                           depth=0.0).arrival
    return conversation_trace(starts, sim_time, seed=SCEN_SEED,
                              home_names=names, shares=shares,
                              turns_mean=turns_mean, think_mean_s=20.0,
                              roam_prob=0.1 if k > 1 else 0.0)


def _fleet_cost_hr(sim, sc: SystemConfig, horizon_s: float) -> float:
    """Time-averaged $/hr of the fleet over the horizon.

    Autoscale points integrate each region's piecewise-constant (n_p, n_d)
    trajectory across its conversion epochs — charging the final allocation
    for the whole run under-bills any point that scaled down mid-run (and
    over-bills one that scaled up).  Fixed points charge the configured
    allocation; PrfaaS nodes are never autoscaled."""
    base = sc.n_prfaas * PRICE_HR["prfaas"]
    if not sim.autoscalers:
        return (base + sc.n_p * PRICE_HR["prefill"]
                + sc.n_d * PRICE_HR["decode"])
    total = base
    for a in sim.autoscalers.values():
        segs = [(0.0,) + tuple(a.initial)] + list(a.conversions)
        dollars = 0.0
        for i, (t, n_p, n_d) in enumerate(segs):
            t_end = segs[i + 1][0] if i + 1 < len(segs) else horizon_s
            dollars += (t_end - t) * (n_p * PRICE_HR["prefill"]
                                      + n_d * PRICE_HR["decode"])
        total += dollars / max(horizon_s, 1e-9)
    return total


def run_scenario(family: str, k: int, policy: str, size: float,
                 tm: ThroughputModel, sc0: SystemConfig, lam0: float,
                 sim_time: float) -> dict:
    names = ("pd",) if k == 1 else tuple(f"pd{i}" for i in range(k))
    shares = SCEN_SHARES[:k] if k > 1 else None
    rate = SCEN_LOAD_FRAC * lam0 * SCEN_BASE_SCALE
    sc = _scaled_system(sc0, SCEN_BASE_SCALE * size)
    tr = _make_trace(family, rate, sim_time, k, names, shares)
    rc = RouterConfig(threshold_boost=1.0) if policy == "static" else None
    cfg = SimConfig(
        arrival_rate=rate, sim_time=sim_time, seed=SCEN_SEED,
        engine="vector", vector_dt=0.25, ttft_slo_s=SLO_TTFT_S,
        link_gbps=LINK_GBPS_1 * SCEN_BASE_SCALE, link_fluctuation=0.1,
        autoscale=(policy == "autoscale"), pd_clusters=k,
        pd_shares=shares,
        pd_link_gbps=tuple(g * SCEN_BASE_SCALE for g in LINK_GBPS_3[:k])
        if k > 1 else None,
        pd_mesh_gbps=10.0 * SCEN_BASE_SCALE if k > 1 else 0.0)
    sim = PrfaasSimulator(tm, sc, Workload(), cfg, router_cfg=rc)
    sim.inject_soa_trace(tr)
    t0 = time.time()
    m = sim.run()
    wall = time.time() - t0
    horizon_h = sim_time / 3600.0
    completed = max(m["completed"], 1)
    cost_hr = _fleet_cost_hr(sim, sc, sim_time)
    return {
        "family": family, "pd_clusters": k, "policy": policy, "size": size,
        "requests": len(tr), "wall_s": round(wall, 3),
        "offered_rps": round(rate, 2),
        "throughput_rps": round(m["throughput_rps"], 3),
        "goodput_rps": round(m["goodput_rps"], 3),
        "slo_attainment": round(m["slo_attainment"], 4),
        "ttft_mean_s": round(m["ttft_mean"], 3),
        "ttft_p99_s": round(m["ttft_p99"], 3),
        "egress_gbps": round(m["egress_gbps"], 3),
        "fleet_cost_hr": round(cost_hr, 2),
        "cost_per_mreq": round(cost_hr * horizon_h / (completed / 1e6), 2),
        "clusters": {name: {"slo_attainment": round(c["slo_attainment"], 4),
                            "goodput_rps": round(c["goodput_rps"], 3)}
                     for name, c in m["clusters"].items()},
    }


def pareto_frontier(points) -> list:
    """Non-dominated (cost_per_mreq down, slo_attainment up) subset,
    sorted by cost — the curve a capacity planner walks."""
    frontier = []
    for p in sorted(points, key=lambda p: (p["cost_per_mreq"],
                                           -p["slo_attainment"])):
        if not frontier or p["slo_attainment"] > \
                frontier[-1]["slo_attainment"] + 1e-12:
            frontier.append(p)
    return frontier


def run_scenarios(sim_time: float, sizes=SIZES) -> dict:
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc0, lam0, _ = tm.grid_search(4, 8, 100e9 / 8)
    points = []
    for family, k, policy, size in itertools.product(
            FAMILIES, SCEN_K, POLICIES, sizes):
        p = run_scenario(family, k, policy, size, tm, sc0, lam0, sim_time)
        points.append(p)
        emit(f"scenario/{family}_k{k}_{policy}_x{size}", p["wall_s"] * 1e6,
             f"att={p['slo_attainment']:.3f} "
             f"good={p['goodput_rps']:.1f}rps "
             f"${p['cost_per_mreq']:.0f}/Mreq")
    frontier = {fam: [{kk: p[kk] for kk in
                       ("size", "pd_clusters", "policy", "cost_per_mreq",
                        "slo_attainment", "goodput_rps", "ttft_p99_s")}
                      for p in pareto_frontier(
                          [p for p in points if p["family"] == fam])]
                for fam in FAMILIES}
    for fam, front in frontier.items():
        emit(f"scenario/frontier_{fam}", 0.0,
             " -> ".join(f"${f['cost_per_mreq']:.0f}@"
                         f"{f['slo_attainment']:.3f}" for f in front))
    return {"sim_time_s": sim_time, "seed": SCEN_SEED,
            "slo_ttft_s": SLO_TTFT_S, "price_hr": PRICE_HR,
            "base_scale": SCEN_BASE_SCALE, "sizes": list(sizes),
            "n_points": len(points), "points": points,
            "frontier": frontier}


def main(smoke: bool = False, out_path: str = "BENCH_scenario_grid.json"):
    sim_time = 120.0 if smoke else 300.0
    points = []
    t_start = time.time()
    for bf, sigma, fluct, k in itertools.product(
            BURST_FACTORS, LENGTH_SIGMAS, FLUCTUATIONS, PD_CLUSTERS):
        p = run_point(bf, sigma, fluct, k, sim_time)
        points.append(p)
        p90 = "n/a" if p["ttft_p90_s"] is None else f"{p['ttft_p90_s']:.2f}s"
        emit(f"grid/bf{bf}_sg{sigma}_fl{fluct}_k{k}", p["wall_s"] * 1e6,
             f"thr={p['throughput_rps']:.2f}rps "
             f"p90={p90} egress={p['egress_gbps']:.1f}Gbps")
    scenarios = run_scenarios(sim_time=240.0 if smoke else 600.0,
                              sizes=(0.6, 1.75) if smoke else SIZES)
    out = {"sim_time_s": sim_time, "seed": 17, "load_frac": 0.7,
           "slo_ttft_s": SLO_TTFT_S,
           "wall_total_s": round(time.time() - t_start, 2),
           "n_points": len(points), "points": points,
           "scenarios": scenarios, "frontier": scenarios.pop("frontier")}
    write_json(out_path, out)
    emit("grid/total", out["wall_total_s"] * 1e6,
         f"{len(points)}grid+{scenarios['n_points']}scenario pts "
         f"-> {out_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short sim horizon for CI")
    args = ap.parse_args()
    main(smoke=args.smoke)

"""Figure-style scenario grid over the event-driven simulator.

Sweeps the four stressors the ROADMAP asked for, now affordable with the
exact event engine:

  * burst_factor      — MMPP arrival burstiness (mean-preserving duty cycle)
  * length skew       — log-normal sigma of the request-length distribution
  * link fluctuation  — OU bandwidth noise on every inter-DC pair link
  * topology          — 1 vs 3 regional PD clusters (star + PD mesh, skewed
                        regional traffic shares, per-region link capacities)

Multi-cluster points run the regionalized control plane: per-home routing
thresholds (reported per point) and session roaming (``ROAM_PROB``), so
the PD<->PD mesh links carry cross-region cache copies.

Every point runs the SAME offered load (a fixed fraction of the paper
deployment's modeled two-cluster capacity) so degradation is attributable
to the stressor, not to re-sizing.  Emits ``BENCH_scenario_grid.json``
with per-point global + per-cluster + per-pair-link metrics.

    PYTHONPATH=src python -m benchmarks.scenario_grid [--smoke]
"""
import argparse
import itertools
import json
import time

from benchmarks.common import emit
from repro.core import (LogNormalLengths, PrfaasSimulator, SimConfig,
                        SystemConfig, ThroughputModel, Workload,
                        paper_h20_profile, paper_h200_profile, split_even)

BURST_FACTORS = (1.0, 2.5)
LENGTH_SIGMAS = (1.0, 1.3)
FLUCTUATIONS = (0.0, 0.3)
PD_CLUSTERS = (1, 3)
SHARES_3 = (0.6, 0.3, 0.1)           # skewed regional traffic
# deliberately skinny Ethernet (mean egress is ~7 Gbps): OU fluctuation can
# push a pair link into congestion, exercising the short-term routing loop
LINK_GBPS_1 = 20.0
LINK_GBPS_3 = (14.0, 8.0, 5.0)       # thinner links to smaller regions
ROAM_PROB = 0.15                     # multi-cluster: sessions switch region


def _system(tm: ThroughputModel, k: int):
    sc, lam, _ = tm.grid_search(4, 9, 100e9 / 8)
    if k == 1:
        return sc, lam
    sc_k = SystemConfig(sc.n_prfaas, sc.n_p, sc.n_d, sc.b_out, sc.threshold,
                        n_p_clusters=tuple(split_even(sc.n_p, k)),
                        n_d_clusters=tuple(split_even(sc.n_d, k)))
    return sc_k, lam


def run_point(bf: float, sigma: float, fluct: float, k: int,
              sim_time: float, load_frac: float = 0.7) -> dict:
    w = Workload(lengths=LogNormalLengths(sigma=sigma), burst_factor=bf,
                 session_prob=0.3)
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, lam = _system(tm, k)
    cfg = SimConfig(
        arrival_rate=load_frac * lam, sim_time=sim_time, seed=17,
        link_gbps=LINK_GBPS_1, link_fluctuation=fluct, engine="event",
        pd_clusters=k,
        pd_shares=SHARES_3[:k] if k > 1 else None,
        pd_link_gbps=LINK_GBPS_3[:k] if k > 1 else None,
        pd_mesh_gbps=10.0 if k > 1 else 0.0,
        roam_prob=ROAM_PROB if k > 1 else 0.0)
    t0 = time.time()
    m = PrfaasSimulator(tm, sc, w, cfg).run()

    def _r(v):
        return round(v, 4) if v == v else None    # NaN -> valid JSON null

    return {
        "burst_factor": bf, "length_sigma": sigma,
        "link_fluctuation": fluct, "pd_clusters": k,
        "offered_rps": round(load_frac * lam, 4),
        "wall_s": round(time.time() - t0, 3),
        "throughput_rps": round(m["throughput_rps"], 4),
        "ttft_mean_s": _r(m["ttft_mean"]),
        "ttft_p90_s": _r(m["ttft_p90"]),
        "egress_gbps": round(m["egress_gbps"], 4),
        "offload_frac": round(m["offload_frac"], 4),
        "thresholds": {name: _r(t) for name, t in m["thresholds"].items()},
        "clusters": {name: {kk: _r(vv) for kk, vv in c.items()}
                     for name, c in m["clusters"].items()},
        "links": {pair: round(s["sent_bytes"] / 1e9, 3)
                  for pair, s in m["links"].items()},
    }


def main(smoke: bool = False, out_path: str = "BENCH_scenario_grid.json"):
    sim_time = 120.0 if smoke else 300.0
    points = []
    t_start = time.time()
    for bf, sigma, fluct, k in itertools.product(
            BURST_FACTORS, LENGTH_SIGMAS, FLUCTUATIONS, PD_CLUSTERS):
        p = run_point(bf, sigma, fluct, k, sim_time)
        points.append(p)
        p90 = "n/a" if p["ttft_p90_s"] is None else f"{p['ttft_p90_s']:.2f}s"
        emit(f"grid/bf{bf}_sg{sigma}_fl{fluct}_k{k}", p["wall_s"] * 1e6,
             f"thr={p['throughput_rps']:.2f}rps "
             f"p90={p90} egress={p['egress_gbps']:.1f}Gbps")
    out = {"sim_time_s": sim_time, "seed": 17, "load_frac": 0.7,
           "wall_total_s": round(time.time() - t_start, 2),
           "n_points": len(points), "points": points}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    emit("grid/total", out["wall_total_s"] * 1e6,
         f"{len(points)}pts -> {out_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short sim horizon for CI")
    args = ap.parse_args()
    main(smoke=args.smoke)

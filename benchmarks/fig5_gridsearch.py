"""Paper Figure 5: the two 1-D slices of the (t, N_p/N_d) grid search.

(a) fix t at optimum, sweep prefill/decode split -> peak at N_p=3, N_d=5;
(b) fix N_p=3, N_d=5, sweep t -> Θ_prfaas/p and Θ_pdp/(1-p) cross at ~19.4K.
"""
import time

from benchmarks.common import emit
from repro.core import (SystemConfig, ThroughputModel, Workload,
                        paper_h20_profile, paper_h200_profile)


def main():
    t0 = time.time()
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc_opt, _, _ = tm.grid_search(4, 8, 100e9 / 8)

    # (a) sweep N_p/N_d at fixed t*
    best = (None, -1.0)
    for n_p in range(1, 8):
        sc = SystemConfig(4, n_p, 8 - n_p, 100e9 / 8, sc_opt.threshold)
        lam = tm.lambda_max(sc)
        if lam > best[1]:
            best = (n_p, lam)
        emit(f"fig5a/np{n_p}_nd{8-n_p}", (time.time() - t0) * 1e6,
             f"lambda={lam:.2f}")
    emit("fig5a/peak", 0.0,
         f"N_p={best[0]} paper=3 "
         f"claim={'REPRODUCED' if best[0] == 3 else 'NOT-REPRODUCED'}")

    # (b) sweep t at N_p=3, N_d=5: the two stage curves cross at t*
    cross_t = None
    prev = None
    for tk in range(2, 65):
        t = tk * 1024.0
        sc = SystemConfig(4, 3, 5, 100e9 / 8, t)
        p = w.lengths.p_gt(t)
        a = tm.theta_prfaas(sc) / p
        b = tm.theta_pdp(sc) / (1 - p)
        if prev is not None and (prev < 0) != ((a - b) < 0):
            cross_t = t
        prev = a - b
        if tk in (4, 8, 16, 19, 20, 24, 32, 48, 64):
            emit(f"fig5b/t{tk}k", 0.0,
                 f"prfaas_over_p={a:.2f} pdp_over_1mp={b:.2f}")
    emit("fig5b/crossing", 0.0,
         f"t*={cross_t/1000:.0f}K paper=19.4K "
         f"claim={'REPRODUCED' if cross_t and abs(cross_t-19400) < 2500 else 'NOT-REPRODUCED'}")
    return cross_t


if __name__ == "__main__":
    main()

"""Shared benchmark utilities: CSV emission, JSON artifacts, timing."""
import json
import os
import time

import jax

ROWS = []
_T0 = time.perf_counter()


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def reset_clock():
    """Restart the per-benchmark wall clock (the harness calls this before
    each module so ``write_json``'s ``bench_wall_s`` is per-module, not
    cumulative across the whole run)."""
    global _T0
    _T0 = time.perf_counter()


def write_json(path: str, payload: dict):
    """Write a BENCH_*.json artifact (and emit a row so the harness log
    records which artifacts a run produced).

    Injects two bookkeeping fields: ``bench_wall_s`` — wall seconds since
    ``reset_clock()`` (module start under ``benchmarks.run``) — and
    ``prev``, a snapshot of the previous run's top-level scalars so a
    full-run regeneration records what the headline numbers moved FROM."""
    payload = dict(payload)
    payload["bench_wall_s"] = round(time.perf_counter() - _T0, 3)
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            prev = {k: v for k, v in old.items()
                    if isinstance(v, (int, float, str, bool))
                    and not isinstance(v, type(None))}
            if prev:
                payload["prev"] = prev
        except (OSError, ValueError):
            pass                       # unreadable old artifact: no snapshot
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    emit(f"artifact/{os.path.basename(path)}", 0.0,
         f"{os.path.getsize(path)}B")


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of a jax callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6

"""Shared benchmark utilities: CSV emission, JSON artifacts, timing."""
import json
import os
import time

import jax

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def write_json(path: str, payload: dict):
    """Write a BENCH_*.json artifact (and emit a row so the harness log
    records which artifacts a run produced)."""
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    emit(f"artifact/{os.path.basename(path)}", 0.0,
         f"{os.path.getsize(path)}B")


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of a jax callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6

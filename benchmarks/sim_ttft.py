"""Paper §4.3 TTFT + bandwidth claims under the full cluster simulator.

Runs the three Table 6 deployments through the discrete-event simulator at
~90% of each deployment's modeled capacity: PrfaaS-PD must beat homogeneous
on mean AND P90 TTFT (paper: -50% / -64%), sustain higher throughput, and
keep egress ~13 Gbps << the 100 Gbps link.

    PYTHONPATH=src python -m benchmarks.sim_ttft [--smoke] [--compare-engines]

``--compare-engines`` times the exact event engine against the legacy
fixed-tick loop on the same scenario/seed and writes BENCH_sim_engine.json.
"""
import argparse
import json
import os
import time

from benchmarks.common import emit
from repro.core import (PrfaasSimulator, SimConfig, SystemConfig,
                        ThroughputModel, Workload, paper_h20_profile,
                        paper_h200_profile)


def run(tag, tm, sc, w, rate, link_gbps=100.0, fluct=0.1, sim_time=900,
        engine="event"):
    t0 = time.time()
    sim = PrfaasSimulator(tm, sc, w, SimConfig(
        arrival_rate=rate, sim_time=sim_time, dt=0.05, seed=7,
        link_gbps=link_gbps, link_fluctuation=fluct, engine=engine))
    m = sim.run()
    us = (time.time() - t0) * 1e6
    emit(f"sim/{tag}/throughput", us, f"{m['throughput_rps']:.2f}rps")
    emit(f"sim/{tag}/ttft", us,
         f"mean={m['ttft_mean']:.2f}s p90={m['ttft_p90']:.2f}s "
         f"p99={m['ttft_p99']:.2f}s")
    emit(f"sim/{tag}/egress", us, f"{m['egress_gbps']:.1f}Gbps "
         f"link_util={m['link_util']:.2f}")
    emit(f"sim/{tag}/offload", us, f"{m['offload_frac']:.2f}")
    return m


def compare_engines(out_path="BENCH_sim_engine.json", sim_time=900):
    """Time event vs tick engines on the identical scenario/arrival trace
    and record the speedup + metric agreement."""
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, lam, _ = tm.grid_search(4, 8, 100e9 / 8)
    out = {"scenario": {"sim_time_s": sim_time, "arrival_rate": 0.85 * lam,
                        "seed": 0, "dt_tick": 0.02}}
    metrics = {}
    for engine in ("event", "tick"):
        t0 = time.time()
        sim = PrfaasSimulator(tm, sc, w, SimConfig(
            arrival_rate=0.85 * lam, sim_time=sim_time, dt=0.02, seed=0,
            engine=engine))
        m = sim.run()
        wall = time.time() - t0
        metrics[engine] = m
        out[engine] = {"wall_s": round(wall, 4),
                       "throughput_rps": round(m["throughput_rps"], 4),
                       "ttft_mean_s": round(m["ttft_mean"], 4),
                       "ttft_p90_s": round(m["ttft_p90"], 4),
                       "egress_gbps": round(m["egress_gbps"], 4)}
    out["speedup_x"] = round(out["tick"]["wall_s"]
                             / max(out["event"]["wall_s"], 1e-9), 2)
    out["ttft_mean_rel_err"] = round(
        abs(metrics["event"]["ttft_mean"] / metrics["tick"]["ttft_mean"] - 1),
        4)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    emit("sim/engine_compare", 0.0,
         f"event={out['event']['wall_s']}s tick={out['tick']['wall_s']}s "
         f"speedup={out['speedup_x']}x "
         f"ttft_err={out['ttft_mean_rel_err']*100:.1f}%")
    return out


def main(smoke: bool = False):
    sim_time = 240 if smoke else 900
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, lam, _ = tm.grid_search(4, 8, 100e9 / 8)
    tm_h = ThroughputModel(None, paper_h20_profile(), w)
    sc_h, lam_h, _ = tm_h.grid_search(0, 12, 0)
    sc_n = SystemConfig(4, 0, 8, 100e9 / 8, 0.0)
    lam_n = tm.lambda_max(sc_n)

    # common offered load = 90% of the homogeneous baseline capacity, so the
    # TTFT comparison is apples-to-apples (same traffic on all systems)...
    common = 0.9 * lam_h
    m_p = run("prfaas_pd@common", tm, sc, w, common, sim_time=sim_time)
    m_h = run("homogeneous@common", tm_h, sc_h, w, common, sim_time=sim_time)
    m_n = run("naive_hetero@common", tm, sc_n, w, common, sim_time=sim_time)
    mean_red = 1 - m_p["ttft_mean"] / m_h["ttft_mean"]
    p90_red = 1 - m_p["ttft_p90"] / m_h["ttft_p90"]
    emit("sim/ttft_reduction_vs_homog", 0.0,
         f"mean=-{mean_red*100:.0f}% p90=-{p90_red*100:.0f}% "
         f"paper=-50%/-64% "
         f"claim={'REPRODUCED' if mean_red > 0.25 and p90_red > 0.35 else 'PARTIAL'}")

    # ...and each system near its own capacity shows the throughput gap
    m_p2 = run("prfaas_pd@own_cap", tm, sc, w, 0.95 * lam, sim_time=sim_time)
    m_h2 = run("homogeneous@own_cap", tm_h, sc_h, w, 0.95 * lam_h,
               sim_time=sim_time)
    m_n2 = run("naive@own_cap", tm, sc_n, w, 0.95 * lam_n, sim_time=sim_time)
    r = m_p2["throughput_rps"] / max(m_h2["throughput_rps"], 1e-9)
    emit("sim/throughput_ratio_vs_homog", 0.0,
         f"{r:.2f}x paper=1.54x "
         f"claim={'REPRODUCED' if r > 1.35 else 'PARTIAL'}")
    emit("sim/egress_within_ethernet", 0.0,
         f"{m_p2['egress_gbps']:.1f}Gbps paper=~13Gbps of 100Gbps "
         f"claim={'REPRODUCED' if m_p2['egress_gbps'] < 25 else 'NOT-REPRODUCED'}")
    return m_p, m_h


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short sim horizon for CI")
    ap.add_argument("--compare-engines", action="store_true",
                    help="write BENCH_sim_engine.json (event vs tick)")
    args = ap.parse_args()
    if args.compare_engines:
        compare_engines(sim_time=240 if args.smoke else 900)
    else:
        main(smoke=args.smoke)

"""Paper §4.3 TTFT + bandwidth claims under the full cluster simulator.

Runs the three Table 6 deployments through the discrete-event simulator at
~90% of each deployment's modeled capacity: PrfaaS-PD must beat homogeneous
on mean AND P90 TTFT (paper: -50% / -64%), sustain higher throughput, and
keep egress ~13 Gbps << the 100 Gbps link.

    PYTHONPATH=src python -m benchmarks.sim_ttft \
        [--smoke] [--compare-engines] [--seed-sweep N]

``--compare-engines`` times the exact event engine against the legacy
fixed-tick loop AND the vectorized SoA engine on the same scenario/seed,
runs the million-request vector scale point, and writes
BENCH_sim_engine.json.  ``--seed-sweep N`` re-runs the equivalence
comparison over N seeds and records min/median/max relative errors
(tick-vs-event and vector-vs-event).
"""
import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import emit, write_json
from repro.core import (PrfaasSimulator, SimConfig, SystemConfig,
                        ThroughputModel, Workload, diurnal_trace,
                        paper_h20_profile, paper_h200_profile)


def run(tag, tm, sc, w, rate, link_gbps=100.0, fluct=0.1, sim_time=900,
        engine="event"):
    t0 = time.time()
    sim = PrfaasSimulator(tm, sc, w, SimConfig(
        arrival_rate=rate, sim_time=sim_time, dt=0.05, seed=7,
        link_gbps=link_gbps, link_fluctuation=fluct, engine=engine))
    m = sim.run()
    us = (time.time() - t0) * 1e6
    emit(f"sim/{tag}/throughput", us, f"{m['throughput_rps']:.2f}rps")
    emit(f"sim/{tag}/ttft", us,
         f"mean={m['ttft_mean']:.2f}s p90={m['ttft_p90']:.2f}s "
         f"p99={m['ttft_p99']:.2f}s")
    emit(f"sim/{tag}/egress", us, f"{m['egress_gbps']:.1f}Gbps "
         f"link_util={m['link_util']:.2f}")
    emit(f"sim/{tag}/offload", us, f"{m['offload_frac']:.2f}")
    return m


VECTOR_DT = 0.05                     # SoA epoch used for equivalence runs


def _run_engine(tm, sc, w, rate, sim_time, seed, engine):
    # NOTE: fluctuation off for the pinned equivalence scenario — OU noise
    # triggers knife-edge congestion episodes whose queue blowups are
    # chaotic under ANY time discretization (the legacy tick engine
    # diverges from the exact engine just as hard as the vector engine
    # there).  The randomized property suite covers fluctuating links.
    t0 = time.time()
    sim = PrfaasSimulator(tm, sc, w, SimConfig(
        arrival_rate=rate, sim_time=sim_time, dt=0.02, seed=seed,
        link_gbps=25.0, link_fluctuation=0.0, engine=engine,
        vector_dt=VECTOR_DT))
    m = sim.run()
    return m, time.time() - t0


def seed_sweep(tm, sc, w, rate, sim_time, n_seeds):
    """Run event/tick/vector over ``n_seeds`` seeds and summarize the
    per-seed relative errors of the approximate engines against the exact
    event engine (min/median/max per metric)."""
    keys = ("throughput_rps", "ttft_mean", "ttft_p90")
    errs = {"tick": {k: [] for k in keys}, "vector": {k: [] for k in keys}}
    for seed in range(n_seeds):
        ref, _ = _run_engine(tm, sc, w, rate, sim_time, seed, "event")
        for engine in ("tick", "vector"):
            m, _ = _run_engine(tm, sc, w, rate, sim_time, seed, engine)
            for k in keys:
                errs[engine][k].append(
                    abs(m[k] / max(ref[k], 1e-12) - 1.0))
    out = {"n_seeds": n_seeds}
    for engine, per_key in errs.items():
        out[engine] = {
            k: {"min": round(float(np.min(v)), 4),
                "median": round(float(np.median(v)), 4),
                "max": round(float(np.max(v)), 4)}
            for k, v in per_key.items()}
        emit(f"sim/seed_sweep/{engine}", 0.0,
             " ".join(f"{k}_max={out[engine][k]['max']*100:.1f}%"
                      for k in keys))
    return out


def vector_scale_point(scale=160, n_requests=1_000_000, horizon=3600.0):
    """The million-session headline: replay a ~``n_requests`` diurnal
    3-region SoA trace through the vector engine on a fleet scaled
    ``scale``x from the paper deployment.  Single-digit-second wall is the
    acceptance bar."""
    w = Workload(session_prob=0.0, burst_factor=1.0)
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc0, _, _ = tm.grid_search(4, 8, 100e9 / 8)
    sc = dataclasses.replace(
        sc0, n_prfaas=sc0.n_prfaas * scale, n_p=sc0.n_p * scale,
        n_d=sc0.n_d * scale, b_out=sc0.b_out * scale)
    rate = n_requests / horizon
    tr = diurnal_trace(rate, horizon, seed=7,
                       home_names=("pd0", "pd1", "pd2"),
                       tz_offsets_s=(0.0, 8 * 3600.0, 16 * 3600.0))
    sim = PrfaasSimulator(tm, sc, w, SimConfig(
        arrival_rate=rate, sim_time=horizon, seed=7, engine="vector",
        vector_dt=1.0, pd_clusters=3, link_gbps=2000.0,
        link_fluctuation=0.15, pool_blocks=2_000_000))
    sim.inject_soa_trace(tr)
    t0 = time.time()
    m = sim.run()
    wall = time.time() - t0
    point = {"requests": len(tr), "scale_x": scale,
             "sim_horizon_s": horizon, "wall_s": round(wall, 3),
             "req_per_wall_s": round(len(tr) / max(wall, 1e-9), 1),
             "throughput_rps": round(m["throughput_rps"], 2),
             "completed": m["completed"],
             "ttft_mean_s": round(m["ttft_mean"], 3),
             "ttft_p90_s": round(m["ttft_p90"], 3)}
    emit("sim/vector_scale", wall * 1e6,
         f"{len(tr)}req wall={wall:.2f}s "
         f"({point['req_per_wall_s']:.0f}req/s "
         f"ttft_mean={point['ttft_mean_s']:.2f}s)")
    return point


def compare_engines(out_path="BENCH_sim_engine.json", sim_time=900,
                    n_seeds=5, smoke=False):
    """Time event vs tick vs vector engines on the identical
    scenario/arrival trace, record speedups + metric agreement, sweep
    seeds, and pin the million-request vector scale point."""
    w = Workload(session_prob=0.35, burst_factor=1.6)
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, lam, _ = tm.grid_search(6, 12, 100e9 / 8)
    rate = 0.7 * lam
    out = {"scenario": {"sim_time_s": sim_time, "arrival_rate": rate,
                        "seed": 0, "dt_tick": 0.02, "vector_dt": VECTOR_DT,
                        "link_gbps": 25.0, "link_fluctuation": 0.0}}
    metrics = {}
    for engine in ("event", "tick", "vector"):
        m, wall = _run_engine(tm, sc, w, rate, sim_time, 0, engine)
        metrics[engine] = m
        out[engine] = {"wall_s": round(wall, 4),
                       "throughput_rps": round(m["throughput_rps"], 4),
                       "ttft_mean_s": round(m["ttft_mean"], 4),
                       "ttft_p90_s": round(m["ttft_p90"], 4),
                       "egress_gbps": round(m["egress_gbps"], 4)}
    out["speedup_x"] = round(out["tick"]["wall_s"]
                             / max(out["event"]["wall_s"], 1e-9), 2)
    out["vector_speedup_x"] = round(out["event"]["wall_s"]
                                    / max(out["vector"]["wall_s"], 1e-9), 2)
    out["ttft_mean_rel_err"] = round(
        abs(metrics["event"]["ttft_mean"] / metrics["tick"]["ttft_mean"] - 1),
        4)
    out["vector_ttft_mean_rel_err"] = round(
        abs(metrics["vector"]["ttft_mean"]
            / max(metrics["event"]["ttft_mean"], 1e-12) - 1), 4)
    out["seed_sweep"] = seed_sweep(tm, sc, w, rate,
                                   min(sim_time, 360), n_seeds)
    out["vector_scale"] = (
        vector_scale_point(scale=16, n_requests=10_000, horizon=360.0)
        if smoke else vector_scale_point())
    write_json(out_path, out)
    emit("sim/engine_compare", 0.0,
         f"event={out['event']['wall_s']}s tick={out['tick']['wall_s']}s "
         f"vector={out['vector']['wall_s']}s "
         f"speedup={out['speedup_x']}x vec={out['vector_speedup_x']}x "
         f"ttft_err={out['ttft_mean_rel_err']*100:.1f}% "
         f"vec_err={out['vector_ttft_mean_rel_err']*100:.1f}%")
    return out


def main(smoke: bool = False):
    sim_time = 240 if smoke else 900
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)
    sc, lam, _ = tm.grid_search(4, 8, 100e9 / 8)
    tm_h = ThroughputModel(None, paper_h20_profile(), w)
    sc_h, lam_h, _ = tm_h.grid_search(0, 12, 0)
    sc_n = SystemConfig(4, 0, 8, 100e9 / 8, 0.0)
    lam_n = tm.lambda_max(sc_n)

    # common offered load = 90% of the homogeneous baseline capacity, so the
    # TTFT comparison is apples-to-apples (same traffic on all systems)...
    common = 0.9 * lam_h
    m_p = run("prfaas_pd@common", tm, sc, w, common, sim_time=sim_time)
    m_h = run("homogeneous@common", tm_h, sc_h, w, common, sim_time=sim_time)
    m_n = run("naive_hetero@common", tm, sc_n, w, common, sim_time=sim_time)
    mean_red = 1 - m_p["ttft_mean"] / m_h["ttft_mean"]
    p90_red = 1 - m_p["ttft_p90"] / m_h["ttft_p90"]
    emit("sim/ttft_reduction_vs_homog", 0.0,
         f"mean=-{mean_red*100:.0f}% p90=-{p90_red*100:.0f}% "
         f"paper=-50%/-64% "
         f"claim={'REPRODUCED' if mean_red > 0.25 and p90_red > 0.35 else 'PARTIAL'}")

    # ...and each system near its own capacity shows the throughput gap
    m_p2 = run("prfaas_pd@own_cap", tm, sc, w, 0.95 * lam, sim_time=sim_time)
    m_h2 = run("homogeneous@own_cap", tm_h, sc_h, w, 0.95 * lam_h,
               sim_time=sim_time)
    m_n2 = run("naive@own_cap", tm, sc_n, w, 0.95 * lam_n, sim_time=sim_time)
    r = m_p2["throughput_rps"] / max(m_h2["throughput_rps"], 1e-9)
    emit("sim/throughput_ratio_vs_homog", 0.0,
         f"{r:.2f}x paper=1.54x "
         f"claim={'REPRODUCED' if r > 1.35 else 'PARTIAL'}")
    emit("sim/egress_within_ethernet", 0.0,
         f"{m_p2['egress_gbps']:.1f}Gbps paper=~13Gbps of 100Gbps "
         f"claim={'REPRODUCED' if m_p2['egress_gbps'] < 25 else 'NOT-REPRODUCED'}")

    # engine comparison artifact rides along with the harness run so
    # BENCH_sim_engine.json (speedups, seed-sweep equivalence, the 1e6
    # vector scale point) regenerates with every full/smoke pass
    compare_engines(sim_time=240 if smoke else 900,
                    n_seeds=2 if smoke else 5, smoke=smoke)
    return m_p, m_h


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short sim horizon for CI")
    ap.add_argument("--compare-engines", action="store_true",
                    help="write BENCH_sim_engine.json (event/tick/vector)")
    ap.add_argument("--seed-sweep", type=int, default=0, metavar="N",
                    help="equivalence sweep over N seeds (implies "
                         "--compare-engines); reports min/median/max "
                         "relative error per engine/metric")
    args = ap.parse_args()
    if args.compare_engines or args.seed_sweep:
        compare_engines(sim_time=240 if args.smoke else 900,
                        n_seeds=args.seed_sweep or (2 if args.smoke else 5),
                        smoke=args.smoke)
    else:
        main(smoke=args.smoke)

"""Paper Table 5: S_kv / T_prefill / Φ_kv of the 1T hybrid case-study model.

Two columns: (a) the paper's measured values (ingested verbatim — the
faithful-reproduction input for Table 6), (b) our independent reconstruction
from the kimi-linear-1t proxy config + H200 roofline. S_kv must match within
~2% (the proxy was calibrated on structure, not on these outputs).
"""
import time

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.hardware import (CHIPS, MIB, AnalyticProfile,
                                 PAPER_TABLE5_LENS, PAPER_TABLE5_SKV_MIB,
                                 PAPER_TABLE5_TPREFILL, paper_h200_profile)


def main():
    cfg = get_config("kimi-linear-1t")
    ours = AnalyticProfile(cfg, CHIPS["h200"], chips_per_instance=8)
    paper = paper_h200_profile()
    worst_skv = 0.0
    for i, l in enumerate(PAPER_TABLE5_LENS):
        skv_ours = cfg.kv_cache_bytes(l) / MIB
        skv_paper = PAPER_TABLE5_SKV_MIB[i]
        rel = abs(skv_ours / skv_paper - 1)
        worst_skv = max(worst_skv, rel)
        emit(f"table5/skv_{l//1024}k", 0.0,
             f"ours={skv_ours:.1f}MiB paper={skv_paper}MiB err={rel*100:.1f}%")
        emit(f"table5/tprefill_{l//1024}k", 0.0,
             f"analytic={ours.t_prefill(l):.2f}s "
             f"paper={PAPER_TABLE5_TPREFILL[i]}s")
        emit(f"table5/phi_kv_{l//1024}k", 0.0,
             f"analytic={ours.kv_throughput(l)*8/1e9:.2f}Gbps "
             f"paper={paper.kv_throughput(l)*8/1e9:.2f}Gbps")
    emit("table5/skv_calibration", 0.0,
         f"worst_err={worst_skv*100:.1f}% "
         f"claim={'REPRODUCED' if worst_skv < 0.02 else 'NOT-REPRODUCED'}")
    return worst_skv


if __name__ == "__main__":
    main()

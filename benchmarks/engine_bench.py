"""Serving hot-path benchmark: pins the recompile-free engine wins in
``BENCH_engine.json`` so regressions fail ``benchmarks.run --smoke``.

Three measurements on a smoke model (harness overhead is exactly what the
tiny model exposes — the quantities below are scheduling tax, not FLOPs):

  * decode tokens/s at ``SLOTS`` active slots — the per-token loop
    (``DecodeEngine.step``: one dispatch + host sync + python bookkeeping
    per token) vs the blocked loop (``step_block``: ``lax.scan`` decode
    block on device, one sync per block).  Acceptance: >= 3x.
  * admission latency — K serial single-request full-cache
    ``dynamic_update_slice`` placements (the old path, reconstructed here)
    vs one batched ``admit_many`` scatter.
  * prefill compile stability — warm the (batch, length) buckets, then run
    a mixed-length workload and count recompiles.  Acceptance: 0.

    PYTHONPATH=src python -m benchmarks.engine_bench [--smoke]
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_json
from repro.configs import get_smoke_config
from repro.models import Model, prepare_decode_caches
from repro.serving.api import Request
from repro.serving.engine import (DecodeEngine, PrefillEngine,
                                  trim_request_cache)

# One KV-cache attention arch (SWA; windowed cache decode) and one
# linear-state arch
# (O(1) recurrent states) — the two regimes of the serving hot path.  The
# headline decode number is the linear-state row: on this CPU container the
# attention smoke model's XLA op-execution floor inside the decode block
# (~0.45ms/token of real compute) caps its measurable speedup near 3x,
# whereas on an accelerator the per-token loop's host tax dominates both.
ARCH_ATTN = "h2o-danube-1.8b"
ARCH_LINEAR = "xlstm-350m"
SLOTS = 16
CAPACITY = 192
PROMPT_LEN = 24
BLOCK = 16


def _mk_requests(cfg, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        (PROMPT_LEN,)).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def bench_decode(tag, model, params, entries, max_new):
    """tokens/s of the per-token loop vs the blocked loop, same workload."""
    eng = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK)
    # warm both compiled paths (admission, step, block) out of the timing
    eng.admit_many(entries)
    eng.step()
    eng.run_until_drained()

    def timed(loop, reps=5):
        # best-of-reps: each rep re-admits the same workload and drains it
        produced = sum(r.max_new_tokens for r, *_ in entries)
        best = float("inf")
        for _ in range(reps):
            eng.admit_many(entries)
            t0 = time.perf_counter()
            loop()
            best = min(best, time.perf_counter() - t0)
        return produced / best, best

    def per_token():
        while eng.active.any():
            eng.step()

    tok_s_step, wall_step = timed(per_token)
    tok_s_block, wall_block = timed(eng.run_until_drained)
    speedup = tok_s_block / tok_s_step
    emit(f"engine/decode_per_token_{tag}", wall_step * 1e6,
         f"{tok_s_step:.1f}tok/s slots={SLOTS}")
    emit(f"engine/decode_block_{tag}", wall_block * 1e6,
         f"{tok_s_block:.1f}tok/s block={BLOCK} speedup={speedup:.2f}x")
    assert speedup > 1.0, (
        f"blocked decode slower than per-token loop ({speedup:.2f}x)")
    return {"slots": SLOTS, "block_size": BLOCK, "new_tokens": max_new,
            "per_token_tok_s": round(tok_s_step, 1),
            "block_tok_s": round(tok_s_block, 1),
            "speedup": round(speedup, 2),
            "block_compiles": eng.block_compiles}


def bench_admission(model, params, entries):
    """K serial full-cache placements (legacy) vs one batched scatter."""
    K = len(entries)
    eng = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK)

    # the old DecodeEngine._place: one jit'd full-cache update per request
    def place_one(caches, one_cache, slot):
        def put(buf, new):
            idx = (0, slot) + (0,) * (buf.ndim - 2)
            return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype),
                                                idx)
        return jax.tree.map(put, caches, one_cache)

    serial_place = jax.jit(place_one, donate_argnums=(0,))

    def serial(caches):
        # the old admit() loop: per request, prepare + one jit'd full-cache
        # update (admit_many does the same prepare, then ONE placement call)
        for slot, (_, _, c, _) in enumerate(entries):
            p = prepare_decode_caches(model.cfg, c, CAPACITY)
            caches = serial_place(caches, p, jnp.int32(slot))
        jax.block_until_ready(jax.tree.leaves(caches)[0])
        return caches

    caches = eng.caches
    caches = serial(caches)                       # warm
    t0 = time.perf_counter()
    caches = serial(caches)
    serial_s = time.perf_counter() - t0
    eng.caches = caches

    eng.admit_many(entries)                       # warm batched path
    eng.run_until_drained(max_steps=0)
    for slot in range(SLOTS):                     # reset slot state
        if eng.active[slot]:
            eng.active[slot] = False
            eng.slot_req[slot] = None
    eng._free.clear()
    eng._free.extend(range(SLOTS))
    eng.outputs.clear()
    t0 = time.perf_counter()
    eng.admit_many(entries)
    jax.block_until_ready(jax.tree.leaves(eng.caches)[0])
    batched_s = time.perf_counter() - t0
    speedup = serial_s / batched_s
    emit("engine/admit_serial", serial_s * 1e6, f"K={K} full-cache updates")
    emit("engine/admit_batched", batched_s * 1e6,
         f"K={K} one scatter, speedup={speedup:.2f}x")
    return {"K": K, "serial_us": round(serial_s * 1e6, 1),
            "batched_us": round(batched_s * 1e6, 1),
            "speedup": round(speedup, 2)}


def bench_prefill_buckets(model, params, cfg, smoke):
    """Mixed-length workload after bucket warmup must not recompile."""
    eng = PrefillEngine(model, params, min_bucket=32)
    rng = np.random.default_rng(1)
    batch, buckets = 4, (32, 64, 128, 256)
    eng.warmup([batch], buckets)
    warm_compiles = eng.compiles
    n_batches = 4 if smoke else 12
    walls = []
    for _ in range(n_batches):
        lens = rng.integers(9, 256, (batch,))
        toks = np.zeros((batch, int(lens.max())), np.int32)
        for i, L in enumerate(lens):
            toks[i, :L] = rng.integers(0, cfg.vocab_size, (L,))
        t0 = time.perf_counter()
        eng.prefill(toks, lens.astype(np.int32))
        walls.append(time.perf_counter() - t0)
    recompiles = eng.compiles - warm_compiles
    emit("engine/prefill_recompiles", float(np.mean(walls)) * 1e6,
         f"{recompiles} recompiles over {n_batches} mixed-length batches "
         f"(warmup={warm_compiles} compiles)")
    assert recompiles == 0, (
        f"{recompiles} prefill recompiles after bucket warmup")
    return {"batch": batch, "buckets": list(buckets),
            "warmup_compiles": warm_compiles,
            "recompiles_after_warmup": recompiles,
            "mixed_batches": n_batches,
            "prefill_mean_us": round(float(np.mean(walls)) * 1e6, 1)}


def _setup(cfg, max_new):
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _mk_requests(cfg, SLOTS, max_new)
    peng = PrefillEngine(model, params, min_bucket=32)
    toks = np.stack([r.tokens for r in reqs])
    lens = np.full((SLOTS,), PROMPT_LEN, np.int32)
    first, caches, _ = peng.prefill(toks, lens)
    entries = [(r, int(first[i]), trim_request_cache(caches, i, PROMPT_LEN),
                PROMPT_LEN) for i, r in enumerate(reqs)]
    return cfg, model, params, entries


def main(smoke: bool = False, out_path: str = "BENCH_engine.json"):
    max_new = 32 if smoke else 64
    cfg_a, model_a, params_a, entries_a = _setup(get_smoke_config(ARCH_ATTN),
                                                 max_new)
    cfg_l, model_l, params_l, entries_l = _setup(
        get_smoke_config(ARCH_LINEAR), max_new)
    decode = {
        "linear_state": bench_decode("linear", model_l, params_l, entries_l,
                                     max_new),
        "attention": bench_decode("attn", model_a, params_a, entries_a,
                                  max_new),
    }
    admission = bench_admission(model_l, params_l, entries_l)
    prefill = bench_prefill_buckets(model_a, params_a, cfg_a, smoke)
    write_json(out_path, {
        "archs": {"linear_state": ARCH_LINEAR, "attention": ARCH_ATTN},
        "smoke": smoke, "backend": jax.default_backend(),
        # headline: block-decode speedup at SLOTS active slots vs the
        # per-token loop (linear-state regime; see module docstring)
        "decode_speedup_at_16_slots": decode["linear_state"]["speedup"],
        "decode": decode, "admission": admission, "prefill": prefill,
    })
    return True


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke)

"""Serving hot-path benchmark: pins the recompile-free engine wins in
``BENCH_engine.json`` so regressions fail ``benchmarks.run --smoke``.

Three measurements on a smoke model (harness overhead is exactly what the
tiny model exposes — the quantities below are scheduling tax, not FLOPs):

  * decode tokens/s at ``SLOTS`` active slots — the per-token loop
    (``DecodeEngine.step``: one dispatch + host sync + python bookkeeping
    per token) vs the blocked loop (``step_block``: ``lax.scan`` decode
    block on device, one sync per block).  Acceptance: >= 3x.
  * admission latency — K serial single-request full-cache
    ``dynamic_update_slice`` placements (the old path, reconstructed here)
    vs one batched ``admit_many`` scatter.
  * prefill compile stability — warm the (batch, length) buckets, then run
    a mixed-length workload and count recompiles.  Acceptance: 0.
  * decode-slot occupancy + goodput at ``SLOTS`` slots under a mixed
    long-prefill + decode load — the PR 5 alternating loop (whole-batch
    prefill, admit waves, drain to empty) vs the continuous
    ``RegionScheduler`` (bucket-exact units, chunk interleave, admission at
    block boundaries).  Acceptance: continuous occupancy strictly above the
    alternating baseline, with 0 recompiles after the warm run.
  * speculative decode (PR 10) — n-gram-drafted multi-token decode on the
    continuous scheduler, k swept against the plain k=0 path on the same
    refilling workload.  Acceptance: some k >= 2 beats plain tokens/s,
    accepted_tokens_per_dispatch > 1.0, token streams identical to k=0,
    one verify compile per draft depth.
  * paged KV (PR 7) — (a) admission latency of the paged page-write
    scatter vs the dense full-slot placement, with 0 admission recompiles
    after ``warmup_admission``; (b) prefix-hit suffix-only prefill at a
    50% hit rate: measured prefilled-token savings plus the analytic
    prefill-FLOP savings, with 0 decode-block recompiles; (c) resident-KV
    headroom — device bytes held by LRU-resident (reusable, reclaimable)
    prefix pages after the workload drains, a capacity the dense layout
    has no counterpart for.

    PYTHONPATH=src python -m benchmarks.engine_bench [--smoke]
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_json
from repro.configs import get_smoke_config
from repro.configs.base import AttentionSpec
from repro.core.blockpool import BlockPool
from repro.core.hardware import CHIPS, AnalyticProfile
from repro.core.prefix_cache import HybridPrefixCache
from repro.models import Model, paged_layout, prepare_decode_caches
from repro.serving.api import PagePin, Request
from repro.serving.engine import (DecodeEngine, PrefillEngine,
                                  RegionScheduler, trim_request_cache)

# One KV-cache attention arch (SWA; windowed cache decode) and one
# linear-state arch
# (O(1) recurrent states) — the two regimes of the serving hot path.  The
# headline decode number is the linear-state row: on this CPU container the
# attention smoke model's XLA op-execution floor inside the decode block
# (~0.45ms/token of real compute) caps its measurable speedup near 3x,
# whereas on an accelerator the per-token loop's host tax dominates both.
ARCH_ATTN = "h2o-danube-1.8b"
ARCH_LINEAR = "xlstm-350m"
ARCH_PAGED = "mistral-nemo-12b"     # full attention: seq pages stay resident
SLOTS = 16
CAPACITY = 192
PROMPT_LEN = 24
BLOCK = 16
PAGE = 16


def _mk_requests(cfg, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        (PROMPT_LEN,)).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def bench_decode(tag, model, params, entries, max_new):
    """tokens/s of the per-token loop vs the blocked loop, same workload."""
    eng = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK)
    # warm both compiled paths (admission, step, block) out of the timing
    eng.admit_many(entries)
    eng.step()
    eng.run_until_drained()

    def timed(loop, reps=5):
        # best-of-reps: each rep re-admits the same workload and drains it
        produced = sum(r.max_new_tokens for r, *_ in entries)
        best = float("inf")
        for _ in range(reps):
            eng.admit_many(entries)
            t0 = time.perf_counter()
            loop()
            best = min(best, time.perf_counter() - t0)
        return produced / best, best

    def per_token():
        while eng.active.any():
            eng.step()

    tok_s_step, wall_step = timed(per_token)
    tok_s_block, wall_block = timed(eng.run_until_drained)
    speedup = tok_s_block / tok_s_step
    emit(f"engine/decode_per_token_{tag}", wall_step * 1e6,
         f"{tok_s_step:.1f}tok/s slots={SLOTS}")
    emit(f"engine/decode_block_{tag}", wall_block * 1e6,
         f"{tok_s_block:.1f}tok/s block={BLOCK} speedup={speedup:.2f}x")
    assert speedup > 1.0, (
        f"blocked decode slower than per-token loop ({speedup:.2f}x)")
    return {"slots": SLOTS, "block_size": BLOCK, "new_tokens": max_new,
            "per_token_tok_s": round(tok_s_step, 1),
            "block_tok_s": round(tok_s_block, 1),
            "speedup": round(speedup, 2),
            "block_compiles": eng.block_compiles}


def bench_admission(model, params, entries):
    """K serial full-cache placements (legacy) vs one batched scatter."""
    K = len(entries)
    eng = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK)

    # the old DecodeEngine._place: one jit'd full-cache update per request
    def place_one(caches, one_cache, slot):
        def put(buf, new):
            idx = (0, slot) + (0,) * (buf.ndim - 2)
            return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype),
                                                idx)
        return jax.tree.map(put, caches, one_cache)

    serial_place = jax.jit(place_one, donate_argnums=(0,))

    def serial(caches):
        # the old admit() loop: per request, prepare + one jit'd full-cache
        # update (admit_many does the same prepare, then ONE placement call)
        for slot, (_, _, c, _) in enumerate(entries):
            p = prepare_decode_caches(model.cfg, c, CAPACITY)
            caches = serial_place(caches, p, jnp.int32(slot))
        jax.block_until_ready(jax.tree.leaves(caches)[0])
        return caches

    caches = eng.caches
    caches = serial(caches)                       # warm
    t0 = time.perf_counter()
    caches = serial(caches)
    serial_s = time.perf_counter() - t0
    eng.caches = caches

    eng.admit_many(entries)                       # warm batched path
    eng.run_until_drained(max_steps=0)
    for slot in range(SLOTS):                     # reset slot state
        if eng.active[slot]:
            eng.active[slot] = False
            eng.slot_req[slot] = None
    eng._free.clear()
    eng._free.extend(range(SLOTS))
    eng.outputs.clear()
    t0 = time.perf_counter()
    eng.admit_many(entries)
    jax.block_until_ready(jax.tree.leaves(eng.caches)[0])
    batched_s = time.perf_counter() - t0
    speedup = serial_s / batched_s
    emit("engine/admit_serial", serial_s * 1e6, f"K={K} full-cache updates")
    emit("engine/admit_batched", batched_s * 1e6,
         f"K={K} one scatter, speedup={speedup:.2f}x")
    return {"K": K, "serial_us": round(serial_s * 1e6, 1),
            "batched_us": round(batched_s * 1e6, 1),
            "speedup": round(speedup, 2)}


def bench_prefill_buckets(model, params, cfg, smoke):
    """Mixed-length workload after bucket warmup must not recompile."""
    eng = PrefillEngine(model, params, min_bucket=32)
    rng = np.random.default_rng(1)
    batch, buckets = 4, (32, 64, 128, 256)
    eng.warmup([batch], buckets)
    warm_compiles = eng.compiles
    n_batches = 4 if smoke else 12
    walls = []
    for _ in range(n_batches):
        lens = rng.integers(9, 256, (batch,))
        toks = np.zeros((batch, int(lens.max())), np.int32)
        for i, L in enumerate(lens):
            toks[i, :L] = rng.integers(0, cfg.vocab_size, (L,))
        t0 = time.perf_counter()
        eng.prefill(toks, lens.astype(np.int32))
        walls.append(time.perf_counter() - t0)
    recompiles = eng.compiles - warm_compiles
    emit("engine/prefill_recompiles", float(np.mean(walls)) * 1e6,
         f"{recompiles} recompiles over {n_batches} mixed-length batches "
         f"(warmup={warm_compiles} compiles)")
    assert recompiles == 0, (
        f"{recompiles} prefill recompiles after bucket warmup")
    return {"batch": batch, "buckets": list(buckets),
            "warmup_compiles": warm_compiles,
            "recompiles_after_warmup": recompiles,
            "mixed_batches": n_batches,
            "prefill_mean_us": round(float(np.mean(walls)) * 1e6, 1)}


LONG_LEN = 200          # past the occupancy bench's max_bucket -> chunked


def _reset_decode(dec: DecodeEngine):
    """Return a DecodeEngine to its post-init state without re-jitting."""
    dec.lengths[:] = 0
    dec.tokens[:] = 0
    dec.active[:] = False
    dec.budget[:] = 0
    dec.slot_req = [None] * dec.num_slots
    dec.outputs = {}
    dec.truncations = 0
    dec.decode_wall_s = dec.slot_busy_s = 0.0
    dec.tokens_out = 0
    dec._free.clear()
    dec._free.extend(range(dec.num_slots))


def bench_occupancy(model, params, cfg, smoke):
    """Occupancy/goodput at SLOTS decode slots, mixed long-prefill + decode
    load: the alternating loop pays one whole-batch prefill (every prompt
    padded to the global max) with decode idle, then drains to empty
    between admit waves — with more requests than slots and ragged decode
    budgets, slots sit idle while each wave's longest stream finishes; the
    scheduler runs bucket-exact units, chunk-interleaves long prompts
    between decode blocks, and refills freed slots at the next boundary."""
    capacity = 384
    n_short, n_long = (20, 4) if smoke else (28, 8)
    hi_new = 48 if smoke else 96
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i,
                    tokens=rng.integers(
                        0, cfg.vocab_size,
                        (PROMPT_LEN if i < n_short else LONG_LEN,)
                    ).astype(np.int32),
                    max_new_tokens=int(rng.integers(8, hi_new + 1)))
            for i in range(n_short + n_long)]
    reqs = [reqs[i] for i in rng.permutation(len(reqs))]   # arrival mix
    peng = PrefillEngine(model, params, min_bucket=32, max_bucket=64)
    peng.warmup([1, 8], [PROMPT_LEN, LONG_LEN])
    dec = DecodeEngine(model, params, SLOTS, capacity, block_size=BLOCK)

    def alternating():
        # faithful PR 5 regime: ONE bucketed prefill call for the whole
        # batch (padded to the longest prompt's chunk multiple), then admit
        # waves that drain all active streams before admitting the rest
        lengths = np.array([len(r.tokens) for r in reqs], np.int32)
        toks = np.zeros((len(reqs), int(lengths.max())), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.tokens)] = r.tokens
        first, caches, _ = peng.prefill(toks, lengths)
        pending = [(r, int(first[i]),
                    trim_request_cache(caches, i, int(lengths[i])),
                    int(lengths[i])) for i, r in enumerate(reqs)]
        while pending:
            n = dec.admit_many(pending)
            pending = pending[n:]
            dec.run_until_drained()

    def continuous():
        sched = RegionScheduler(peng, dec, max_prefill_batch=8)
        for r in reqs:
            sched.submit(r)
        sched.run()

    def timed(fn, reps=2):
        best = (0.0, float("inf"), 0)          # (occupancy, wall, tokens)
        for _ in range(reps):
            _reset_decode(dec)
            t0 = time.perf_counter()
            fn()
            wall = time.perf_counter() - t0
            occ = dec.slot_busy_s / (SLOTS * wall)
            if occ > best[0]:
                best = (occ, wall, dec.tokens_out)
        return best

    # warm run of each regime compiles its batch shapes out of the timing
    _reset_decode(dec)
    alternating()
    _reset_decode(dec)
    continuous()
    warm_compiles = peng.compiles
    alt_occ, alt_wall, alt_toks = timed(alternating)
    con_occ, con_wall, con_toks = timed(continuous)
    recompiles = peng.compiles - warm_compiles
    alt_good, con_good = alt_toks / alt_wall, con_toks / con_wall
    emit("engine/occupancy_alternating", alt_wall * 1e6,
         f"occ={alt_occ:.3f} {alt_good:.1f}tok/s slots={SLOTS}")
    emit("engine/occupancy_continuous", con_wall * 1e6,
         f"occ={con_occ:.3f} {con_good:.1f}tok/s "
         f"gain={con_occ / max(alt_occ, 1e-9):.2f}x")
    assert con_occ > alt_occ, (
        f"continuous scheduler occupancy {con_occ:.3f} not above "
        f"alternating baseline {alt_occ:.3f}")
    assert recompiles == 0, (
        f"{recompiles} prefill recompiles during occupancy bench")
    assert dec.block_compiles in (None, 1), (
        f"decode block recompiled: {dec.block_compiles}")
    return {"slots": SLOTS, "block_size": BLOCK, "capacity": capacity,
            "requests": len(reqs), "long_prompts": n_long,
            "long_len": LONG_LEN, "new_tokens_hi": hi_new,
            "occupancy_continuous": round(con_occ, 4),
            "occupancy_alternating": round(alt_occ, 4),
            "goodput_tok_s_continuous": round(con_good, 1),
            "goodput_tok_s_alternating": round(alt_good, 1),
            "recompiles_after_warmup": recompiles}


SPEC_CAPACITY = 640     # speculative bench KV capacity (long streams so the
SPEC_BLOCK = 16         # n-gram drafter has history to mine)


def bench_spec_decode(model, params, cfg, smoke):
    """Speculative multi-token decode (PR 10 tentpole) at SLOTS slots on the
    continuous scheduler: per-slot n-gram drafts verified k+1-at-a-time in
    one dispatch, greedy acceptance, variable tokens-per-block.  Sweeps
    draft depth k against the k=0 plain path on the SAME workload
    (requests >> slots, so freed slots refill at block boundaries — the
    honest occupancy regime, no drain-tail artifact).  Acceptance: some
    k >= 2 beats plain tokens/s with accepted_tokens_per_dispatch > 1.0,
    token streams identical to k=0, and one verify compile per k."""
    new_tok, n_req, reps = (384, 24, 2) if smoke else (512, 32, 3)
    ks = (0, 2) if smoke else (0, 2, 3)
    rng0 = np.random.default_rng(11)
    prompts = [rng0.integers(0, cfg.vocab_size,
                             (PROMPT_LEN,)).astype(np.int32)
               for _ in range(n_req)]

    def mk():
        return [Request(rid=i, tokens=prompts[i], max_new_tokens=new_tok)
                for i in range(n_req)]

    sweep, outs = {}, {}
    for k in ks:
        peng = PrefillEngine(model, params, min_bucket=32, max_bucket=64)
        dec = DecodeEngine(model, params, SLOTS, SPEC_CAPACITY,
                           block_size=SPEC_BLOCK, spec_k=k, spec_ngram=1)
        sched = RegionScheduler(peng, dec, max_prefill_batch=4)
        for r in mk():
            sched.submit(r)
        sched.run()                         # warm run compiles everything
        outs[k] = {rid: r.output_tokens for rid, r in dec.outputs.items()}
        warm_spec = dec.spec_compiles
        best = float("inf")
        for _ in range(reps):
            dec.outputs.clear()
            dec.tokens_out = 0
            sched = RegionScheduler(peng, dec, max_prefill_batch=4)
            for r in mk():
                sched.submit(r)
            t0 = time.perf_counter()
            sched.run()
            best = min(best, time.perf_counter() - t0)
        produced = n_req * new_tok
        acc = dec.accepted_tokens_per_dispatch
        recompiles = dec.spec_compiles - warm_spec
        assert recompiles == 0, (
            f"k={k}: {recompiles} verify recompiles after warm run")
        sweep[f"k{k}"] = {
            "tok_s": round(produced / best, 1),
            "accepted_tokens_per_dispatch": round(acc, 3),
            "verify_compiles": dec.spec_compiles,
        }
        emit(f"engine/spec_decode_k{k}", best * 1e6,
             f"{produced / best:.1f}tok/s acc/disp={acc:.2f} slots={SLOTS}")

    plain = sweep["k0"]["tok_s"]
    best_k, best_ratio = 0, 1.0
    for k in ks[1:]:
        assert outs[k] == outs[0], (
            f"k={k} speculative tokens diverge from plain greedy")
        r = sweep[f"k{k}"]["tok_s"] / plain
        sweep[f"k{k}"]["speedup_vs_plain"] = round(r, 3)
        if r > best_ratio:
            best_k, best_ratio = k, r
    assert best_k >= 2, (
        f"no draft depth beat plain decode (best ratio {best_ratio:.3f})")
    assert sweep[f"k{best_k}"]["accepted_tokens_per_dispatch"] > 1.0
    emit("engine/spec_decode_speedup", best_ratio,
         f"best k={best_k} vs plain, token-identical")
    return {"slots": SLOTS, "capacity": SPEC_CAPACITY,
            "block_size": SPEC_BLOCK, "requests": n_req,
            "new_tokens": new_tok, "spec_ngram": 1,
            "best_k": best_k, "speedup_vs_plain": round(best_ratio, 3),
            "accepted_tokens_per_dispatch":
                sweep[f"k{best_k}"]["accepted_tokens_per_dispatch"],
            "sweep": sweep}


def bench_paged_admission(model, params, entries):
    """Paged page-write admission scatter vs the dense full-slot placement,
    same prefilled entries.  The paged path must run recompile-free after
    ``warmup_admission`` on the same traffic shape."""
    def timed_admit(eng, reps=5):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            eng.admit_many(entries)
            jax.block_until_ready(jax.tree.leaves(eng.caches)[0])
            best = min(best, time.perf_counter() - t0)
            for slot in range(SLOTS):
                if eng.active[slot]:
                    eng._retire(slot)
            eng.outputs.clear()
        return best

    dense = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK)
    timed_admit(dense, reps=1)                    # warm the dense scatter
    dense_s = timed_admit(dense)

    dec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                       paged=True, page_tokens=PAGE)
    dec.warmup_admission([SLOTS], [PROMPT_LEN])
    warm = dec.admit_compiles
    paged_s = timed_admit(dec)
    recompiles = dec.admit_compiles - warm
    speedup = dense_s / paged_s
    emit("engine/admit_dense_layout", dense_s * 1e6,
         f"K={len(entries)} full-slot placement")
    emit("engine/admit_paged_layout", paged_s * 1e6,
         f"K={len(entries)} page scatter, vs dense={speedup:.2f}x, "
         f"{recompiles} recompiles")
    assert recompiles == 0, (
        f"{recompiles} paged-admission recompiles after warmup_admission")
    s = dec.pool.stats
    assert s["allocated"] == s["freed"] + s["evicted"] + dec.pool.resident

    # int8 wire admission (PR 8): the quantized pytree admits directly,
    # dequantization fused into the page scatter — same recompile-free
    # contract after warmup_admission warms the wire program variant
    from repro.models.kvcache import quantize_cache_for_wire
    wdec = DecodeEngine(model, params, SLOTS, CAPACITY, block_size=BLOCK,
                        paged=True, page_tokens=PAGE)
    wdec.wire_admission = True
    wdec.warmup_admission([SLOTS], [PROMPT_LEN])
    warm_w = wdec.admit_compiles
    wire_entries = [(r, f, quantize_cache_for_wire(c)[0], L)
                    for (r, f, c, L) in entries]

    def timed_wire(reps=5):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            wdec.admit_many(wire_entries)
            jax.block_until_ready(jax.tree.leaves(wdec.caches)[0])
            best = min(best, time.perf_counter() - t0)
            for slot in range(SLOTS):
                if wdec.active[slot]:
                    wdec._retire(slot)
            wdec.outputs.clear()
        return best

    wire_s = timed_wire()
    wire_recompiles = wdec.admit_compiles - warm_w
    emit("engine/admit_wire_paged", wire_s * 1e6,
         f"K={len(entries)} int8 dequant-in-scatter, "
         f"{wire_recompiles} recompiles")
    assert wire_recompiles == 0, (
        f"{wire_recompiles} wire-admission recompiles after "
        "warmup_admission")
    return {"K": len(entries), "dense_us": round(dense_s * 1e6, 1),
            "paged_us": round(paged_s * 1e6, 1),
            "speedup_vs_dense": round(speedup, 2),
            "admit_warmup_compiles": warm,
            "admit_recompiles_after_warmup": recompiles,
            "wire_admit_us": round(wire_s * 1e6, 1),
            "wire_admit_warmup_compiles": warm_w,
            "wire_admit_recompiles_after_warmup": wire_recompiles}


def bench_paged_prefix(model, params, cfg, smoke):
    """Suffix-only prefill at a 50% prefix-hit rate: half the workload
    shares a registered 64-token prefix and resumes from its device pages,
    so only the suffix is prefilled.  Reports the measured prefilled-token
    savings, the analytic prefill-FLOP savings (incremental
    ``prefill_flops(L) - prefill_flops(c)`` charge per hit), and the
    resident-KV headroom the paged pool retains after the drain."""
    capacity = 192
    lay = paged_layout(cfg, capacity, PAGE, 1)
    has_state = any(not isinstance(b.mixer, AttentionSpec)
                    for g in cfg.groups for b in g.blocks)
    pool = BlockPool(SLOTS * capacity // PAGE, PAGE)
    cache = HybridPrefixCache(pool, 0, 1, has_full_attn=lay.seq_cols > 0,
                              has_linear=lay.ring_cols > 0 or has_state)
    peng = PrefillEngine(model, params, min_bucket=32, max_bucket=64)
    dec = DecodeEngine(model, params, SLOTS, capacity, block_size=BLOCK,
                       paged=True, pool=pool, page_tokens=PAGE)
    dec.on_admit = lambda req, L, ids, snap: cache.insert_device(
        [int(t) for t in req.tokens], ids, snap)
    sched = RegionScheduler(peng, dec, max_prefill_batch=8)

    rng = np.random.default_rng(7)
    c_len, total_len = 64, 128
    prefix = rng.integers(0, cfg.vocab_size, (c_len,)).astype(np.int32)
    sched.submit(Request(rid=999, tokens=prefix, max_new_tokens=2))
    sched.run()                        # registers the prefix pages
    blocks_warm = dec.block_compiles

    n = 8 if smoke else 12
    reqs = []
    for i in range(n):
        rest = rng.integers(0, cfg.vocab_size,
                            (total_len - c_len,)).astype(np.int32)
        if i % 2 == 0:                 # 50% of the workload hits
            toks = np.concatenate([prefix, rest])
            c, ids, snap = cache.match_resume([int(t) for t in toks])
            assert c == c_len, "registered prefix must be resumable"
            pool.retain(ids)
            reqs.append(Request(rid=i, tokens=toks, max_new_tokens=8,
                                device_pin=PagePin(c, ids, snap)))
        else:
            cold = rng.integers(0, cfg.vocab_size,
                                (c_len,)).astype(np.int32)
            reqs.append(Request(rid=i, tokens=np.concatenate([cold, rest]),
                                max_new_tokens=8))
    before = peng.tokens_prefilled
    t0 = time.perf_counter()
    for r in reqs:
        sched.submit(r)
    sched.run()
    wall = time.perf_counter() - t0
    prefilled = peng.tokens_prefilled - before
    total = sum(len(r.tokens) for r in reqs)
    assert prefilled == total - (n // 2) * c_len, (
        "prefix hits must prefill only the uncached suffix")
    token_savings = 1.0 - prefilled / total

    profile = AnalyticProfile(cfg, CHIPS["h200"], 8)
    f_full = profile.prefill_flops(total_len)
    f_inc = f_full - profile.prefill_flops(c_len)
    flop_savings = 1.0 - ((n // 2) * f_inc + (n - n // 2) * f_full) \
        / (n * f_full)

    decode_recompiles = dec.block_compiles - blocks_warm
    s = pool.stats
    assert s["allocated"] == s["freed"] + s["evicted"] + pool.resident
    resident_bytes = pool.resident * dec.page_bytes
    emit("engine/paged_prefix_hits", wall * 1e6,
         f"n={n} hit_rate=0.5 token_savings={token_savings:.3f} "
         f"flop_savings={flop_savings:.3f}")
    emit("engine/paged_resident_kv", float(resident_bytes),
         f"{pool.resident}/{pool.num_blocks} pages resident after drain, "
         f"{decode_recompiles} decode recompiles")
    assert decode_recompiles == 0, (
        f"{decode_recompiles} paged decode-block recompiles after warm run")
    assert resident_bytes > 0, "registered prefix pages must stay resident"
    return {"requests": n, "hit_rate": 0.5, "prompt_len": total_len,
            "cached_len": c_len,
            "tokens_prefilled": int(prefilled),
            "tokens_submitted": int(total),
            "token_savings_frac": round(token_savings, 4),
            "flop_savings_frac": round(flop_savings, 4),
            "decode_recompiles": decode_recompiles,
            "resident_kv_bytes": int(resident_bytes),
            "resident_pages": pool.resident,
            "pool_pages": pool.num_blocks,
            "wall_us": round(wall * 1e6, 1)}


def _setup(cfg, max_new):
    model = Model(cfg, use_kernels=False)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _mk_requests(cfg, SLOTS, max_new)
    peng = PrefillEngine(model, params, min_bucket=32)
    toks = np.stack([r.tokens for r in reqs])
    lens = np.full((SLOTS,), PROMPT_LEN, np.int32)
    first, caches, _ = peng.prefill(toks, lens)
    entries = [(r, int(first[i]), trim_request_cache(caches, i, PROMPT_LEN),
                PROMPT_LEN) for i, r in enumerate(reqs)]
    return cfg, model, params, entries


def main(smoke: bool = False, out_path: str = "BENCH_engine.json"):
    max_new = 32 if smoke else 64
    cfg_a, model_a, params_a, entries_a = _setup(get_smoke_config(ARCH_ATTN),
                                                 max_new)
    cfg_l, model_l, params_l, entries_l = _setup(
        get_smoke_config(ARCH_LINEAR), max_new)
    decode = {
        "linear_state": bench_decode("linear", model_l, params_l, entries_l,
                                     max_new),
        "attention": bench_decode("attn", model_a, params_a, entries_a,
                                  max_new),
    }
    admission = bench_admission(model_l, params_l, entries_l)
    prefill = bench_prefill_buckets(model_a, params_a, cfg_a, smoke)
    occupancy = bench_occupancy(model_a, params_a, cfg_a, smoke)
    cfg_p, model_p, params_p, entries_p = _setup(get_smoke_config(ARCH_PAGED),
                                                 max_new)
    paged = {
        "admission": bench_paged_admission(model_p, params_p, entries_p),
        "prefix": bench_paged_prefix(model_p, params_p, cfg_p, smoke),
    }
    speculative = bench_spec_decode(model_p, params_p, cfg_p, smoke)
    write_json(out_path, {
        "archs": {"linear_state": ARCH_LINEAR, "attention": ARCH_ATTN,
                  "paged": ARCH_PAGED},
        "smoke": smoke, "backend": jax.default_backend(),
        # headline: block-decode speedup at SLOTS active slots vs the
        # per-token loop (linear-state regime; see module docstring)
        "decode_speedup_at_16_slots": decode["linear_state"]["speedup"],
        # headline: continuous-scheduler decode-slot occupancy vs the
        # alternating-loop baseline, same mixed load at SLOTS slots
        "occupancy_at_16_slots": occupancy["occupancy_continuous"],
        "occupancy_alternating_baseline":
            occupancy["occupancy_alternating"],
        # headline: measured prefilled-token savings from device-resident
        # prefix pages at a 50% hit rate, and the KV bytes those resident
        # pages keep reusable after the workload drains
        "paged_token_savings_at_50pct_hits":
            paged["prefix"]["token_savings_frac"],
        "paged_resident_kv_bytes": paged["prefix"]["resident_kv_bytes"],
        # headline: speculative decode vs plain at SLOTS slots on the
        # continuous scheduler, greedy token-identical, and the mean
        # tokens each verify dispatch emitted at the best draft depth
        "spec_decode_speedup_at_16_slots": speculative["speedup_vs_plain"],
        "accepted_tokens_per_dispatch":
            speculative["accepted_tokens_per_dispatch"],
        "decode": decode, "admission": admission, "prefill": prefill,
        "occupancy": occupancy, "paged": paged, "speculative": speculative,
    })
    return True


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke)

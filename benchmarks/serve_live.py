"""Live-path guard: run the multi-region launcher end to end with
``--cross-validate`` and assert the shared control plane holds — a frozen
3-region smoke run must agree with the simulator replay on EVERY request's
route, and int8 wire compression must measure > 1x.  Drift here fails
``benchmarks.run --smoke`` (and thus ``tests/test_bench_smoke.py``) instead
of rotting silently.

    PYTHONPATH=src python -m benchmarks.serve_live [--smoke]
"""
import time

from benchmarks.common import emit


def main(smoke: bool = False):
    from repro.launch.serve import build_parser, run_serve

    argv = ["--arch", "kimi-linear-1t", "--smoke",
            "--requests", "12" if smoke else "24",
            "--batches", "3",
            "--pd-clusters", "3",
            "--threshold", "64",
            "--link-gbps", "10.0",
            "--pd-mesh-gbps", "10.0",
            "--wire-compression",
            "--freeze-thresholds",
            "--cross-validate"]
    t0 = time.time()
    report = run_serve(build_parser().parse_args(argv))
    us = (time.time() - t0) * 1e6
    cv = report["cross_validate"]
    dm = report["deployment"]
    emit("serve/route_agreement", us,
         f"{cv['route_agreement']:.3f} ({cv['requests']}req)")
    emit("serve/wire_compression", us, f"{dm['wire_compression']:.2f}x")
    emit("serve/egress_ratio", us, f"{cv['egress_bytes']['ratio']:.2f}")
    emit("serve/occupancy", us,
         f"{dm['occupancy']:.3f} goodput={dm['goodput_tok_s']:.1f}tok/s")
    assert cv["route_agreement"] == 1.0, (
        f"frozen-threshold route agreement broke: {cv['mismatches']}")
    assert dm["wire_compression"] > 1.0, "int8 wire compression inactive"
    assert 0.0 < dm["occupancy"] <= 1.0, (
        f"scheduler occupancy out of range: {dm['occupancy']}")


if __name__ == "__main__":
    main()

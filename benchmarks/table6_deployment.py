"""Paper Table 6: PrfaaS-PD vs homogeneous PD vs naive heterogeneous PD.

The faithful reproduction: paper Table 5 profile -> our throughput model
(Eqs. 1-8) + grid search -> the paper's deployment comparison. Every paper
number is asserted side by side.
"""
import math
import time

from benchmarks.common import emit
from repro.core import (SystemConfig, ThroughputModel, Workload,
                        paper_h20_profile, paper_h200_profile)

PAPER = {
    "prfaas": {"t": 19_400, "n": (4, 3, 5), "theta": (1.61, 1.64, 3.91),
               "lam": 3.24},
    "homog": {"n": (0, 9, 3), "theta": (None, 2.11, 2.35), "lam": 2.11},
    "naive": {"n": (4, 0, 8), "theta": (2.45, None, 6.25), "lam": 2.45},
    "ratio": (1.54, 1.16), "egress_gbps": 13.0, "offload": 0.496,
    "l_long": 44_000,
}


def main():
    t0 = time.time()
    w = Workload()
    tm = ThroughputModel(paper_h200_profile(), paper_h20_profile(), w)

    sc, lam, _ = tm.grid_search(4, 8, 100e9 / 8)
    us = (time.time() - t0) * 1e6
    p = w.lengths.p_gt(sc.threshold)
    emit("table6/prfaas_pd/threshold", us,
         f"t={sc.threshold/1000:.1f}K paper={PAPER['prfaas']['t']/1000:.1f}K")
    emit("table6/prfaas_pd/alloc", us,
         f"N={sc.n_prfaas}/{sc.n_p}/{sc.n_d} paper=4/3/5")
    emit("table6/prfaas_pd/thetas", us,
         f"{tm.theta_prfaas(sc):.2f}/{tm.theta_pdp(sc):.2f}/"
         f"{tm.theta_pdd(sc):.2f} paper=1.61/1.64/3.91")
    emit("table6/prfaas_pd/lambda_max", us, f"{lam:.2f} paper=3.24")
    emit("table6/prfaas_pd/offload_frac", us,
         f"{p:.3f} paper={PAPER['offload']}")
    emit("table6/prfaas_pd/l_long", us,
         f"{w.lengths.mean_above(sc.threshold)/1000:.1f}K paper=44K")
    emit("table6/prfaas_pd/egress", us,
         f"{tm.egress_load(sc)*8/1e9:.1f}Gbps paper=~13Gbps")

    tm_h = ThroughputModel(None, paper_h20_profile(), w)
    sc_h, lam_h, _ = tm_h.grid_search(0, 12, 0)
    emit("table6/homogeneous/alloc", us,
         f"N=-/{sc_h.n_p}/{sc_h.n_d} paper=-/9/3")
    emit("table6/homogeneous/lambda_max", us, f"{lam_h:.2f} paper=2.11")

    sc_n = SystemConfig(4, 0, 8, 100e9 / 8, 0.0)
    lam_n = tm.lambda_max(sc_n)
    emit("table6/naive_hetero/lambda_max", us, f"{lam_n:.2f} paper=2.45")

    r1, r2 = lam / lam_h, lam_n / lam_h
    ok = abs(r1 - 1.54) < 0.08 and abs(r2 - 1.16) < 0.06
    emit("table6/ratios", us,
         f"prfaas={r1:.2f}x naive={r2:.2f}x paper=1.54x/1.16x "
         f"claim={'REPRODUCED' if ok else 'NOT-REPRODUCED'}")

    # beyond-paper: int8 KV on the wire (paper §5 points at KIVI/CacheGen).
    # In the paper's 100 Gbps setup PrfaaS is compute-bound (no change);
    # in a bandwidth-bound deployment (8 PrfaaS instances, 10 Gbps link)
    # halving wire bytes re-opens the egress ceiling.
    sc_bw, lam_bw, _ = tm.grid_search(8, 8, 10e9 / 8)
    sc_bc, lam_bc, _ = tm.grid_search(8, 8, 10e9 / 8, kv_wire_compression=2.0)
    emit("table6/beyond_paper/kv_wire_int8", us,
         f"bandwidth-bound lam {lam_bw:.2f}->{lam_bc:.2f} "
         f"(+{(lam_bc/lam_bw-1)*100:.0f}%) t {sc_bw.threshold/1000:.1f}K->"
         f"{sc_bc.threshold/1000:.1f}K")

    # equal-cost variant (paper §4.4: ~15% gain at equal cost).
    # H200:H20 street-price ratio ~2:1 -> 32 H200 ~ 64 H20-equivalents;
    # compare against a 128-H20 homogeneous cluster (16 instances).
    sc_eq, lam_eq, _ = tm_h.grid_search(0, 16, 0)
    gain = lam / lam_eq
    emit("table6/equal_cost_gain", us,
         f"{(gain-1)*100:.0f}% paper=~15% (2:1 price ratio assumption)")
    return r1, r2


if __name__ == "__main__":
    main()

"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table6]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
import argparse
import sys
import time
import traceback

from benchmarks import (fig5_gridsearch, kernel_bench, sim_ttft,
                        table3_kv_throughput, table5_profile,
                        table6_deployment)

MODULES = {
    "table3": table3_kv_throughput,    # Table 3 / Figure 2 (Φ_kv by model)
    "table5": table5_profile,          # Table 5 (1T hybrid profile)
    "table6": table6_deployment,       # Table 6 (deployment comparison)
    "fig5": fig5_gridsearch,           # Figure 5 (grid search slices)
    "sim": sim_ttft,                   # §4.3 TTFT/egress via simulator
    "kernels": kernel_bench,           # supporting kernel micro-bench
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(MODULES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(MODULES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            MODULES[name].main()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()

"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table6] [--smoke]

``--smoke`` shortens simulator horizons so the whole harness finishes in
seconds (CI / tier-1 verify); full runs reproduce the paper-scale numbers.
Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
import argparse
import inspect
import sys
import time
import traceback

from benchmarks import (common, engine_bench, fig5_gridsearch, kernel_bench,
                        scenario_grid, serve_live, sim_ttft,
                        table3_kv_throughput, table5_profile,
                        table6_deployment)

MODULES = {
    "table3": table3_kv_throughput,    # Table 3 / Figure 2 (Φ_kv by model)
    "table5": table5_profile,          # Table 5 (1T hybrid profile)
    "table6": table6_deployment,       # Table 6 (deployment comparison)
    "fig5": fig5_gridsearch,           # Figure 5 (grid search slices)
    "sim": sim_ttft,                   # §4.3 TTFT/egress via simulator
    "grid": scenario_grid,             # burst x skew x fluct x topology grid
    "kernels": kernel_bench,           # micro-bench + machine calibration
    "engine": engine_bench,            # serving hot path (decode/admit/buckets)
    "serve": serve_live,               # live launcher + policy/actual x-val
}


def _call_main(mod, smoke: bool):
    main = mod.main
    if smoke and "smoke" in inspect.signature(main).parameters:
        return main(smoke=True)
    return main()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(MODULES))
    ap.add_argument("--smoke", action="store_true",
                    help="short horizons; finish the harness in seconds")
    args = ap.parse_args()
    names = [args.only] if args.only else list(MODULES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        common.reset_clock()       # per-module bench_wall_s in artifacts
        try:
            _call_main(MODULES[name], args.smoke)
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()

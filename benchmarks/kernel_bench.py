"""Kernel micro-bench: us/call of the lowerable serving-path implementations
(the Pallas kernels target TPU; on this CPU container we time the jnp
chunked/banded forms that the dry-run compiles, plus interpret-mode kernel
calls at small shapes for correctness-path coverage) + derived FLOPs.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops
from repro.models import chunked_attention as chk

RNG = np.random.default_rng(0)


def mk(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def main():
    B, H, S, D = 1, 8, 2048, 128
    q, k, v = mk(B, H, S, D), mk(B, H, S, D), mk(B, H, S, D)

    f = jax.jit(lambda q, k, v: chk.flash_chunked(q, k, v, causal=True))
    us = time_fn(f, q, k, v)
    flops = 4 * B * H * S * S * D / 2
    emit("kernel/flash_chunked_2k", us,
         f"{flops/us*1e-3:.1f}GFLOP/s flops={flops:.2e}")

    f = jax.jit(lambda q, k, v: chk.swa_banded(q, k, v, window=512))
    us = time_fn(f, q, k, v)
    flops = 4 * B * H * S * (512 + 512) * D
    emit("kernel/swa_banded_2k_w512", us, f"{flops/us*1e-3:.1f}GFLOP/s")

    la = -0.1 * jnp.abs(mk(B, H, S))
    s0 = jnp.zeros((B, H, D, D))
    f = jax.jit(lambda *a: chk.gla_chunked_jnp(*a, chunk=64)[0])
    us = time_fn(f, q, k, v, la, s0)
    flops = 4 * B * H * S * D * D
    emit("kernel/gla_chunked_2k", us, f"{flops/us*1e-3:.1f}GFLOP/s")

    beta = jnp.asarray(RNG.uniform(0.1, 1, (B, H, S)).astype(np.float32))
    kn = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    f = jax.jit(lambda *a: chk.delta_chunked_jnp(*a, chunk=64)[0])
    us = time_fn(f, q, kn, v, la, beta, s0)
    emit("kernel/delta_chunked_2k", us, f"{flops/us*1e-3:.1f}GFLOP/s")

    # decode over a long cache (the ref einsum path used in serve_step)
    qd, kc, vc = mk(4, H, D), mk(4, H, 8192, D), mk(4, H, 8192, D)
    lens = jnp.full((4,), 8192, jnp.int32)
    f = jax.jit(lambda *a: ops.decode_attention(*a, use_kernel=False))
    us = time_fn(f, qd, kc, vc, lens)
    emit("kernel/decode_ref_8k_cache", us,
         f"bytes={4*H*8192*D*2*4:.2e}")

    # Pallas interpret-mode correctness-path timing (small shapes)
    qs, ks, vs = mk(1, 2, 256, 64), mk(1, 2, 256, 64), mk(1, 2, 256, 64)
    from repro.kernels.flash_attn import flash_attention
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                interpret=True))
    us = time_fn(f, qs, ks, vs, iters=3, warmup=1)
    emit("kernel/pallas_flash_interpret_256", us, "correctness-path")
    return True


if __name__ == "__main__":
    main()

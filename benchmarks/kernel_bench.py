"""Kernel micro-bench: us/call of the lowerable serving-path implementations
(the Pallas kernels target TPU; on this CPU container we time the jnp
chunked/banded forms that the dry-run compiles, plus interpret-mode kernel
calls at small shapes for correctness-path coverage) + derived FLOPs.

Also runs the **measured-kernel calibration sweep**: this machine's peak
FLOP/s and memory bandwidth, then achieved FLOP/s of the prefill-shaped
kernels (causal attention + FFN matmul) over a range of prefill lengths.
The per-length MFU points plus the ``analysis.calibrate`` saturation-curve
fit are written to ``BENCH_kernel.json``, which ``CalibratedProfile``
consumes so routing thresholds and simulated service times derive from the
hardware the engines actually run on.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, write_json
from repro.analysis.calibrate import calibration_from_points, calibration_to_json
from repro.kernels import ops
from repro.models import chunked_attention as chk

RNG = np.random.default_rng(0)

SWEEP_LENS = (256, 512, 1024, 2048, 4096)
SWEEP_LENS_SMOKE = (128, 256, 512, 1024)
SWEEP_HEADS, SWEEP_DIM, SWEEP_DMODEL = 8, 128, 1024


def mk(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def measure_machine(smoke: bool = False):
    """Measured peaks of THIS machine: dense-matmul FLOP/s and a streaming
    copy's bytes/s (the roofline ceilings the MFU sweep is relative to).
    The matmul probe matches the sweep's FFN width (SWEEP_DMODEL) in both
    modes so the MFU denominator is measured on comparable shapes."""
    n = SWEEP_DMODEL
    a, b = mk(n, n), mk(n, n)
    f = jax.jit(lambda a, b: a @ b)
    us = time_fn(f, a, b, iters=3, warmup=2)
    peak_flops = 2.0 * n ** 3 / (us * 1e-6)
    emit("kernel/machine_peak_matmul", us, f"{peak_flops/1e9:.1f}GFLOP/s")

    m = (1 << 22) if smoke else (1 << 24)
    x = mk(m)
    g = jax.jit(lambda x: x * 1.000001 + 0.5)
    us = time_fn(g, x, iters=3, warmup=2)
    mem_bw = 2.0 * m * 4 / (us * 1e-6)               # read + write f32
    emit("kernel/machine_mem_bw", us, f"{mem_bw/1e9:.1f}GB/s")
    return peak_flops, mem_bw


def prefill_sweep(peak_flops: float, smoke: bool = False):
    """Achieved FLOP/s of prefill-shaped work vs prefill length -> MFU(l).

    Per length l: causal flash attention (B=1, H, l, D) plus the matching
    FFN-style matmul (l, d) @ (d, 4d) @ (4d, d) — the two shapes that
    dominate a real prefill — timed together; MFU(l) is their combined
    achieved FLOP/s over the measured matmul peak.
    """
    B, H, D, d = 1, SWEEP_HEADS, SWEEP_DIM, SWEEP_DMODEL
    w1, w2 = mk(d, 4 * d), mk(4 * d, d)
    attn = jax.jit(lambda q, k, v: chk.flash_chunked(q, k, v, causal=True))
    ffn = jax.jit(lambda x, w1, w2: (x @ w1) @ w2)
    points = []
    for l in (SWEEP_LENS_SMOKE if smoke else SWEEP_LENS):
        q, k, v = mk(B, H, l, D), mk(B, H, l, D), mk(B, H, l, D)
        x = mk(l, d)
        us_a = time_fn(attn, q, k, v, iters=2, warmup=1)
        us_f = time_fn(ffn, x, w1, w2, iters=2, warmup=1)
        f_attn = 2.0 * B * H * l * l * D              # qk + pv, causal half
        f_ffn = 2.0 * l * d * 4 * d * 2
        achieved = (f_attn + f_ffn) / ((us_a + us_f) * 1e-6)
        points.append({"l": l, "attn_us": round(us_a, 2),
                       "ffn_us": round(us_f, 2),
                       "flops": f_attn + f_ffn,
                       "achieved_flops": achieved})
    # a sweep shape can amortize overhead better than the square probe; the
    # MFU denominator is the max of both so mfu <= 1 by construction and
    # fit_mfu_curve never hits its clamp on inconsistent measurements
    peak_used = max(peak_flops, *(p["achieved_flops"] for p in points))
    for p in points:
        p["mfu"] = p["achieved_flops"] / peak_used
        emit(f"kernel/prefill_sweep_{p['l']}", p["attn_us"] + p["ffn_us"],
             f"{p['achieved_flops']/1e9:.1f}GFLOP/s mfu={p['mfu']:.3f}")
    return points, peak_used


def interpret_kernel_points():
    """Interpret-mode sweep points for the fused serving-path kernels
    (PR 8): fused-masking GLA/delta chunked state, quantize-on-write, and
    block-table paged prefill.  Small shapes — these pin the
    correctness-path cost into BENCH_kernel.json (the TPU kernels
    themselves are timed on device), so a kernel that silently falls off
    its fused path shows up as bench drift."""
    from repro.kernels.delta import delta_chunked_fused
    from repro.kernels.gla import gla_chunked_fused
    from repro.kernels.paged_prefill_attn import paged_prefill_attention
    from repro.kernels.quantize import quantize_int8_fused
    out = {}

    B, H, S, D = 2, 2, 256, 64
    q, k, v = mk(B, H, S, D), mk(B, H, S, D), mk(B, H, S, D)
    la = -0.1 * jnp.abs(mk(B, H, S))
    lens = jnp.asarray([S, 173], jnp.int32)
    f = jax.jit(lambda *a: gla_chunked_fused(*a, chunk=64,
                                             interpret=True)[0])
    us = time_fn(f, q, k, v, la, lens, iters=3, warmup=1)
    emit("kernel/pallas_gla_fused_interpret_256", us, "fused in-VMEM mask")
    out["gla_fused_us"] = round(us, 2)

    beta = jnp.asarray(RNG.uniform(0.1, 1, (B, H, S)).astype(np.float32))
    kn = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    f = jax.jit(lambda *a: delta_chunked_fused(*a, chunk=64,
                                               interpret=True)[0])
    us = time_fn(f, q, kn, v, la, beta, lens, iters=3, warmup=1)
    emit("kernel/pallas_delta_fused_interpret_256", us, "fused in-VMEM mask")
    out["delta_fused_us"] = round(us, 2)

    x = mk(2, 4, 256, 64)
    f = jax.jit(lambda x: quantize_int8_fused(x, interpret=True)[0])
    us = time_fn(f, x, iters=3, warmup=1)
    emit("kernel/pallas_quantize_interpret_128k", us,
         "absmax+encode one pass")
    out["quantize_fused_us"] = round(us, 2)

    Hq, Hkv, T, N, C, Ssuf = 4, 2, 16, 4, 32, 32
    P = N + 2
    kp, vp = mk(Hkv, P, T, D), mk(Hkv, P, T, D)
    tbl = jnp.asarray(
        np.stack([RNG.choice(P, size=N, replace=False)]).astype(np.int32))
    ks2, vs2 = mk(1, Hkv, Ssuf, D), mk(1, Hkv, Ssuf, D)
    qc = mk(1, Hq, C, D)
    f = jax.jit(lambda *a: paged_prefill_attention(*a, interpret=True))
    us = time_fn(f, qc, kp, vp, tbl, ks2, vs2, iters=3, warmup=1)
    emit("kernel/pallas_paged_prefill_interpret_96", us,
         "table-direct prior + causal suffix")
    out["paged_prefill_us"] = round(us, 2)
    return out


def main(smoke: bool = False, out_path: str = "BENCH_kernel.json"):
    B, H, S, D = 1, 8, 2048, 128
    q, k, v = mk(B, H, S, D), mk(B, H, S, D), mk(B, H, S, D)

    f = jax.jit(lambda q, k, v: chk.flash_chunked(q, k, v, causal=True))
    us = time_fn(f, q, k, v)
    flops = 4 * B * H * S * S * D / 2
    emit("kernel/flash_chunked_2k", us,
         f"{flops/us*1e-3:.1f}GFLOP/s flops={flops:.2e}")

    f = jax.jit(lambda q, k, v: chk.swa_banded(q, k, v, window=512))
    us = time_fn(f, q, k, v)
    flops = 4 * B * H * S * (512 + 512) * D
    emit("kernel/swa_banded_2k_w512", us, f"{flops/us*1e-3:.1f}GFLOP/s")

    la = -0.1 * jnp.abs(mk(B, H, S))
    s0 = jnp.zeros((B, H, D, D))
    f = jax.jit(lambda *a: chk.gla_chunked_jnp(*a, chunk=64)[0])
    us = time_fn(f, q, k, v, la, s0)
    flops = 4 * B * H * S * D * D
    emit("kernel/gla_chunked_2k", us, f"{flops/us*1e-3:.1f}GFLOP/s")

    beta = jnp.asarray(RNG.uniform(0.1, 1, (B, H, S)).astype(np.float32))
    kn = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    f = jax.jit(lambda *a: chk.delta_chunked_jnp(*a, chunk=64)[0])
    us = time_fn(f, q, kn, v, la, beta, s0)
    emit("kernel/delta_chunked_2k", us, f"{flops/us*1e-3:.1f}GFLOP/s")

    # decode over a long cache (the ref einsum path used in serve_step)
    qd, kc, vc = mk(4, H, D), mk(4, H, 8192, D), mk(4, H, 8192, D)
    lens = jnp.full((4,), 8192, jnp.int32)
    f = jax.jit(lambda *a: ops.decode_attention(*a, use_kernel=False))
    us = time_fn(f, qd, kc, vc, lens)
    emit("kernel/decode_ref_8k_cache", us,
         f"bytes={4*H*8192*D*2*4:.2e}")

    # Pallas interpret-mode correctness-path timing (small shapes)
    qs, ks, vs = mk(1, 2, 256, 64), mk(1, 2, 256, 64), mk(1, 2, 256, 64)
    from repro.kernels.flash_attn import flash_attention
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                interpret=True))
    us = time_fn(f, qs, ks, vs, iters=3, warmup=1)
    emit("kernel/pallas_flash_interpret_256", us, "correctness-path")
    interpret_points = interpret_kernel_points()

    # measured-kernel calibration: machine peaks + MFU(l) sweep + fit
    peak_flops, mem_bw = measure_machine(smoke)
    points, peak_used = prefill_sweep(peak_flops, smoke)
    calib = calibration_from_points([(p["l"], p["mfu"]) for p in points],
                                    peak_used, mem_bw)
    emit("kernel/calibration_fit", 0.0,
         f"mfu_max={calib.mfu_max:.3f} l_half={calib.l_half:.0f}")
    write_json(out_path, {
        "machine": {"peak_flops": peak_used, "mem_bw": mem_bw,
                    "matmul_probe_flops": peak_flops,
                    "backend": jax.default_backend()},
        "sweep": {"heads": SWEEP_HEADS, "head_dim": SWEEP_DIM,
                  "d_model": SWEEP_DMODEL, "smoke": smoke},
        "points": points,
        "interpret_points": interpret_points,
        "calibration": calibration_to_json(calib),
    })
    return True


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke)
